//! Serving metrics: latency distribution, throughput, batch occupancy,
//! per-variant routing counts, session-level streaming counters, and
//! fault/delivery accounting (DESIGN.md §10).

use std::collections::BTreeMap;
use std::time::Instant;

use super::delivery::DeliveryStats;
use crate::streaming::StreamStats;
use crate::util::percentile;

/// Fault-tolerance counters (DESIGN.md §10), all monotone.  "exec" is the
/// batch device path, "step" the stream decode path; `timeouts` and
/// `failed` count *requests* that ended in a terminal non-delivered
/// outcome, while the retry/fault counters count device calls.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// batch device-call retries (attempts beyond the first)
    pub exec_retries: u64,
    /// batch device calls that exhausted retries or their deadline
    pub exec_faults: u64,
    /// stream decode-step retries
    pub step_retries: u64,
    /// stream decode steps that exhausted retries or their deadline
    pub step_faults: u64,
    /// requests answered `DeadlineExceeded`
    pub timeouts: u64,
    /// requests answered `Failed`
    pub failed: u64,
    /// requests rerouted to a cheaper variant after a quarantine
    pub downgrades: u64,
}

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    latencies: Vec<f64>,
    batch_sizes: Vec<usize>,
    per_variant: BTreeMap<String, usize>,
    rejected: usize,
    /// decode steps executed by the streaming scheduler
    decode_steps: usize,
    /// real session rows across all decode steps
    decode_rows: usize,
    /// latest session-table snapshot: (active sessions, manager counters)
    stream: Option<(usize, StreamStats)>,
    faults: FaultCounters,
    /// per `from->to` quarantine-downgrade routing counts
    downgrades: BTreeMap<String, u64>,
    /// latest delivery-monitor snapshot (stream forecast outboxes)
    delivery: Option<DeliveryStats>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            latencies: Vec::new(),
            batch_sizes: Vec::new(),
            per_variant: BTreeMap::new(),
            rejected: 0,
            decode_steps: 0,
            decode_rows: 0,
            stream: None,
            faults: FaultCounters::default(),
            downgrades: BTreeMap::new(),
            delivery: None,
        }
    }

    /// Batch device-call retries beyond the first attempt.
    pub fn record_exec_retries(&mut self, retries: usize) {
        self.faults.exec_retries += retries as u64;
    }

    /// A batch device call exhausted its retries or deadline.
    pub fn record_exec_fault(&mut self) {
        self.faults.exec_faults += 1;
    }

    /// Stream decode-step retries beyond the first attempt.
    pub fn record_step_retries(&mut self, retries: usize) {
        self.faults.step_retries += retries as u64;
    }

    /// A stream decode step exhausted its retries or deadline.
    pub fn record_step_fault(&mut self) {
        self.faults.step_faults += 1;
    }

    /// `n` requests answered with a terminal `DeadlineExceeded`.
    pub fn record_timeouts(&mut self, n: usize) {
        self.faults.timeouts += n as u64;
    }

    /// `n` requests answered with a terminal `Failed`.
    pub fn record_failed(&mut self, n: usize) {
        self.faults.failed += n as u64;
    }

    /// A request was rerouted off a quarantined variant.
    pub fn record_downgrade(&mut self, from: &str, to: &str) {
        self.faults.downgrades += 1;
        *self.downgrades.entry(format!("{from}->{to}")).or_insert(0) += 1;
    }

    pub fn faults(&self) -> FaultCounters {
        self.faults
    }

    /// Latest delivery-monitor counters (stream forecast outboxes).
    pub fn set_delivery(&mut self, stats: DeliveryStats) {
        self.delivery = Some(stats);
    }

    pub fn delivery(&self) -> Option<DeliveryStats> {
        self.delivery
    }

    /// One streaming decode step served `rows` sessions.
    pub fn record_decode_step(&mut self, rows: usize) {
        self.decode_steps += 1;
        self.decode_rows += rows;
    }

    /// Latest session-table snapshot from the `SessionManager`.
    pub fn set_stream(&mut self, active: usize, stats: StreamStats) {
        self.stream = Some((active, stats));
    }

    /// Latest session-table snapshot, if any decode activity recorded one.
    pub fn stream_snapshot(&self) -> Option<(usize, StreamStats)> {
        self.stream
    }

    pub fn decode_steps(&self) -> usize {
        self.decode_steps
    }

    pub fn decode_rows(&self) -> usize {
        self.decode_rows
    }

    /// Mean sessions per decode step (streaming batch occupancy).
    pub fn decode_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.decode_rows as f64 / self.decode_steps as f64
    }

    pub fn record_batch(&mut self, variant: &str, batch: usize, latencies: &[f64]) {
        self.batch_sizes.push(batch);
        self.latencies.extend_from_slice(latencies);
        *self.per_variant.entry(variant.to_string()).or_insert(0) += latencies.len();
    }

    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    pub fn served(&self) -> usize {
        self.latencies.len()
    }

    pub fn rejected(&self) -> usize {
        self.rejected
    }

    pub fn throughput(&self) -> f64 {
        self.served() as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let mut l = self.latencies.clone();
        (
            percentile(&mut l, 50.0),
            percentile(&mut l, 95.0),
            percentile(&mut l, 99.0),
        )
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn per_variant(&self) -> &BTreeMap<String, usize> {
        &self.per_variant
    }

    pub fn report(&self) -> String {
        let (p50, p95, p99) = self.latency_percentiles();
        let mut s = format!(
            "served={} rejected={} throughput={:.1}/s p50={:.1}ms p95={:.1}ms p99={:.1}ms occupancy={:.2}\n",
            self.served(),
            self.rejected,
            self.throughput(),
            p50 * 1e3,
            p95 * 1e3,
            p99 * 1e3,
            self.mean_batch_occupancy(),
        );
        for (v, n) in &self.per_variant {
            s.push_str(&format!("  {v}: {n}\n"));
        }
        if self.decode_steps > 0 || self.stream.is_some() {
            s.push_str(&format!(
                "streaming: decode_steps={} rows={} occupancy={:.2}\n",
                self.decode_steps,
                self.decode_rows,
                self.decode_occupancy(),
            ));
            if let Some((active, st)) = &self.stream {
                s.push_str(&format!(
                    "  sessions: active={} admitted={} evicted_lru={} evicted_ttl={} \
                     reroutes={} probes={} points={} requeued={} quarantined={}\n",
                    active,
                    st.admitted,
                    st.evicted_capacity,
                    st.evicted_ttl,
                    st.reroutes,
                    st.probes,
                    st.appended_points,
                    st.requeued_windows,
                    st.quarantined,
                ));
            }
        }
        let f = &self.faults;
        if *f != FaultCounters::default() {
            s.push_str(&format!(
                "faults: exec_retries={} exec_faults={} step_retries={} step_faults={} \
                 timeouts={} failed={} downgrades={}\n",
                f.exec_retries,
                f.exec_faults,
                f.step_retries,
                f.step_faults,
                f.timeouts,
                f.failed,
                f.downgrades,
            ));
            for (route, n) in &self.downgrades {
                s.push_str(&format!("  downgrade {route}: {n}\n"));
            }
        }
        if let Some(d) = &self.delivery {
            s.push_str(&format!(
                "delivery: enqueued={} acked={} redelivered={} expired_undelivered={} \
                 dropped_overflow={} pending={}\n",
                d.enqueued,
                d.acked,
                d.redelivered,
                d.expired_undelivered,
                d.dropped_overflow,
                d.pending,
            ));
        }
        // Which ISA the merge kernel dispatched to (DESIGN.md §11) — the
        // observable contract for "is SIMD actually on in this serving
        // process", and what tests/dispatch_env.rs asserts against.
        s.push_str(&format!("kernel: {}\n", crate::merging::simd::dispatch_report()));
        s
    }
}

/// Sum two fault-counter snapshots (for the cross-shard roll-up).
fn sum_faults(a: FaultCounters, b: FaultCounters) -> FaultCounters {
    FaultCounters {
        exec_retries: a.exec_retries + b.exec_retries,
        exec_faults: a.exec_faults + b.exec_faults,
        step_retries: a.step_retries + b.step_retries,
        step_faults: a.step_faults + b.step_faults,
        timeouts: a.timeouts + b.timeouts,
        failed: a.failed + b.failed,
        downgrades: a.downgrades + b.downgrades,
    }
}

/// Sum two delivery-ledger snapshots.  Every field is either a monotone
/// count or (`pending`) an instantaneous queue depth, so summation keeps
/// the per-shard ledger identity
/// `enqueued == acked + expired_undelivered + dropped_overflow + pending`
/// intact — pinned by `merged_ledger_identity_survives_summation`.
pub fn sum_delivery(a: DeliveryStats, b: DeliveryStats) -> DeliveryStats {
    DeliveryStats {
        enqueued: a.enqueued + b.enqueued,
        acked: a.acked + b.acked,
        redelivered: a.redelivered + b.redelivered,
        expired_undelivered: a.expired_undelivered + b.expired_undelivered,
        dropped_overflow: a.dropped_overflow + b.dropped_overflow,
        pending: a.pending + b.pending,
    }
}

/// Merge per-shard metrics into one process-level report (DESIGN.md §12):
/// a summary line with cross-shard totals, summed fault and delivery
/// counters (ledger identity preserved — see [`sum_delivery`]), then each
/// shard's full [`Metrics::report`] indented under a `shard=<i>` header.
/// Percentiles are deliberately **not** merged: quantiles don't sum, so
/// they stay per-shard where they are meaningful.
pub fn merged_report(shards: &[&Metrics]) -> String {
    let served: usize = shards.iter().map(|m| m.served()).sum();
    let rejected: usize = shards.iter().map(|m| m.rejected()).sum();
    let decode_steps: usize = shards.iter().map(|m| m.decode_steps()).sum();
    let decode_rows: usize = shards.iter().map(|m| m.decode_rows()).sum();
    let mut s = format!(
        "process: shards={} served={served} rejected={rejected} decode_steps={decode_steps} \
         decode_rows={decode_rows}\n",
        shards.len(),
    );
    let faults = shards
        .iter()
        .map(|m| m.faults())
        .fold(FaultCounters::default(), sum_faults);
    if faults != FaultCounters::default() {
        s.push_str(&format!(
            "faults: exec_retries={} exec_faults={} step_retries={} step_faults={} \
             timeouts={} failed={} downgrades={}\n",
            faults.exec_retries,
            faults.exec_faults,
            faults.step_retries,
            faults.step_faults,
            faults.timeouts,
            faults.failed,
            faults.downgrades,
        ));
    }
    if shards.iter().any(|m| m.delivery().is_some()) {
        let d = shards
            .iter()
            .filter_map(|m| m.delivery())
            .fold(DeliveryStats::default(), sum_delivery);
        s.push_str(&format!(
            "delivery: enqueued={} acked={} redelivered={} expired_undelivered={} \
             dropped_overflow={} pending={}\n",
            d.enqueued, d.acked, d.redelivered, d.expired_undelivered, d.dropped_overflow, d.pending,
        ));
    }
    for (i, m) in shards.iter().enumerate() {
        s.push_str(&format!("shard={i}\n"));
        for line in m.report().lines() {
            s.push_str("  ");
            s.push_str(line);
            s.push('\n');
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new();
        m.record_batch("v1", 4, &[0.010, 0.012, 0.011, 0.013]);
        m.record_batch("v2", 2, &[0.020, 0.022]);
        m.record_rejected();
        assert_eq!(m.served(), 6);
        assert_eq!(m.rejected(), 1);
        assert_eq!(m.per_variant()["v1"], 4);
        assert!((m.mean_batch_occupancy() - 3.0).abs() < 1e-12);
        let (p50, p95, p99) = m.latency_percentiles();
        assert!(p50 <= p95 && p95 <= p99);
        assert!(m.report().contains("v2: 2"));
    }

    #[test]
    fn report_names_the_kernel_isa() {
        let report = Metrics::new().report();
        assert!(report.contains("kernel: isa="), "{report}");
        assert!(report.contains("features="), "{report}");
    }

    #[test]
    fn streaming_section_appears_once_recorded() {
        let mut m = Metrics::new();
        assert!(!m.report().contains("streaming:"));
        m.record_decode_step(3);
        m.record_decode_step(1);
        assert_eq!(m.decode_steps(), 2);
        assert_eq!(m.decode_rows(), 4);
        assert!((m.decode_occupancy() - 2.0).abs() < 1e-12);
        m.set_stream(7, StreamStats { admitted: 9, reroutes: 1, ..StreamStats::default() });
        let report = m.report();
        assert!(report.contains("decode_steps=2"));
        assert!(report.contains("active=7"));
        assert!(report.contains("admitted=9"));
        assert_eq!(m.stream_snapshot().unwrap().0, 7);
    }

    #[test]
    fn fault_and_delivery_sections_appear_once_recorded() {
        let mut m = Metrics::new();
        let clean = m.report();
        assert!(!clean.contains("faults:") && !clean.contains("delivery:"));
        m.record_exec_retries(2);
        m.record_exec_fault();
        m.record_step_retries(1);
        m.record_step_fault();
        m.record_timeouts(3);
        m.record_failed(4);
        m.record_downgrade("v2", "v1");
        m.record_downgrade("v2", "v1");
        let f = m.faults();
        assert_eq!(
            (f.exec_retries, f.exec_faults, f.step_retries, f.step_faults),
            (2, 1, 1, 1)
        );
        assert_eq!((f.timeouts, f.failed, f.downgrades), (3, 4, 2));
        m.set_delivery(DeliveryStats {
            enqueued: 10,
            acked: 6,
            redelivered: 1,
            expired_undelivered: 2,
            dropped_overflow: 0,
            pending: 2,
        });
        let report = m.report();
        assert!(report.contains("faults: exec_retries=2"));
        assert!(report.contains("timeouts=3 failed=4 downgrades=2"));
        assert!(report.contains("downgrade v2->v1: 2"));
        assert!(report.contains("delivery: enqueued=10"));
        assert!(report.contains("expired_undelivered=2"));
        assert!(report.contains("pending=2"));
        assert_eq!(m.delivery().unwrap().acked, 6);
    }

    fn balanced(
        enqueued: u64,
        acked: u64,
        redelivered: u64,
        expired: u64,
        dropped: u64,
    ) -> DeliveryStats {
        let stats = DeliveryStats {
            enqueued,
            acked,
            redelivered,
            expired_undelivered: expired,
            dropped_overflow: dropped,
            pending: enqueued - acked - expired - dropped,
        };
        assert_eq!(
            stats.enqueued,
            stats.acked + stats.expired_undelivered + stats.dropped_overflow + stats.pending,
            "test fixture must balance"
        );
        stats
    }

    /// The satellite contract for the cross-shard roll-up: summing
    /// per-shard ledgers (each individually balanced) yields a ledger
    /// that still satisfies
    /// `enqueued == acked + expired_undelivered + dropped_overflow + pending`.
    #[test]
    fn merged_ledger_identity_survives_summation() {
        let mut a = Metrics::new();
        a.record_batch("v1", 2, &[0.010, 0.011]);
        a.set_delivery(balanced(10, 4, 1, 2, 1));
        let mut b = Metrics::new();
        b.record_batch("v2", 1, &[0.020]);
        b.record_rejected();
        b.record_failed(1);
        b.set_delivery(balanced(7, 7, 0, 0, 0));
        let c = Metrics::new(); // idle shard: no delivery snapshot at all
        let merged = sum_delivery(a.delivery().unwrap(), b.delivery().unwrap());
        assert_eq!(
            merged.enqueued,
            merged.acked + merged.expired_undelivered + merged.dropped_overflow + merged.pending,
            "ledger identity must survive summation: {merged:?}"
        );
        assert_eq!((merged.enqueued, merged.acked, merged.pending), (17, 11, 3));
        let report = merged_report(&[&a, &b, &c]);
        assert!(report.contains("process: shards=3 served=3 rejected=1"), "{report}");
        assert!(report.contains("delivery: enqueued=17"), "{report}");
        assert!(report.contains("pending=3"), "{report}");
        assert!(report.contains("faults: ") && report.contains("failed=1"), "{report}");
        for i in 0..3 {
            assert!(report.contains(&format!("shard={i}\n")), "{report}");
        }
        // per-shard sections are indented copies of each shard's report
        assert!(report.contains("  served=2 "), "{report}");
        assert!(report.contains("  served=1 "), "{report}");
        assert!(report.contains("  served=0 "), "{report}");
    }
}
