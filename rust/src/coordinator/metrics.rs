//! Serving metrics: latency distribution, throughput, batch occupancy,
//! per-variant routing counts, session-level streaming counters, and
//! fault/delivery accounting (DESIGN.md §10, §13).
//!
//! All distributions live in bounded log-linear [`Histogram`]s
//! (`obs::hist`), so a `Metrics` holds **no per-request storage**: its
//! heap footprint is constant in the number of requests served (pinned
//! by `memory_is_constant_in_request_count`).  Because histograms merge
//! losslessly (exact bucket/count/sum identities), the cross-shard
//! roll-up can answer true process-level percentiles — see
//! [`merged_report`] / [`merged_json`].

use std::collections::BTreeMap;
use std::time::Instant;

use super::delivery::DeliveryStats;
use crate::json::Json;
use crate::obs::{Histogram, ObsConfig, Stage};
use crate::streaming::StreamStats;

/// Fault-tolerance counters (DESIGN.md §10), all monotone.  "exec" is the
/// batch device path, "step" the stream decode path; `timeouts` and
/// `failed` count *requests* that ended in a terminal non-delivered
/// outcome, while the retry/fault counters count device calls.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// batch device-call retries (attempts beyond the first)
    pub exec_retries: u64,
    /// batch device calls that exhausted retries or their deadline
    pub exec_faults: u64,
    /// stream decode-step retries
    pub step_retries: u64,
    /// stream decode steps that exhausted retries or their deadline
    pub step_faults: u64,
    /// requests answered `DeadlineExceeded`
    pub timeouts: u64,
    /// requests answered `Failed`
    pub failed: u64,
    /// requests rerouted to a cheaper variant after a quarantine
    pub downgrades: u64,
}

/// Merge-efficiency telemetry for one variant: how many tokens entered
/// the merge pipeline vs how many reached the device (DESIGN.md §13).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CompressionStats {
    /// merge-pipeline invocations (batches / incremental folds)
    pub calls: u64,
    /// tokens entering layer 0, summed over calls
    pub tokens_in: u64,
    /// tokens surviving the last layer, summed over calls
    pub tokens_out: u64,
    /// merge layers run, summed over calls
    pub layers: u64,
}

impl CompressionStats {
    /// Aggregate compression ratio `tokens_in / tokens_out` (1.0 when
    /// nothing was merged; > 1.0 when merging shrank the batch).
    pub fn ratio(&self) -> f64 {
        if self.tokens_out == 0 {
            1.0
        } else {
            self.tokens_in as f64 / self.tokens_out as f64
        }
    }

    /// Mean merge layers per call.
    pub fn mean_layers(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.layers as f64 / self.calls as f64
        }
    }
}

/// Entropy-band routing telemetry for one variant: how often the router
/// picked it and the entropy range of the windows that landed there.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RouteStats {
    pub decisions: u64,
    entropy_sum: f64,
    entropy_min: f64,
    entropy_max: f64,
}

impl Default for RouteStats {
    fn default() -> RouteStats {
        RouteStats {
            decisions: 0,
            entropy_sum: 0.0,
            entropy_min: f64::INFINITY,
            entropy_max: f64::NEG_INFINITY,
        }
    }
}

impl RouteStats {
    pub fn entropy_mean(&self) -> f64 {
        if self.decisions == 0 {
            0.0
        } else {
            self.entropy_sum / self.decisions as f64
        }
    }

    pub fn entropy_min(&self) -> f64 {
        if self.decisions == 0 {
            0.0
        } else {
            self.entropy_min
        }
    }

    pub fn entropy_max(&self) -> f64 {
        if self.decisions == 0 {
            0.0
        } else {
            self.entropy_max
        }
    }
}

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    served: usize,
    /// request latencies, seconds (bounded log-linear histogram)
    latency: Histogram,
    /// batch occupancies (rows per formed batch)
    batch: Histogram,
    /// per-stage durations, indexed by [`Stage::idx`]
    stages: Vec<Histogram>,
    per_variant: BTreeMap<String, usize>,
    /// per-variant merge-efficiency telemetry
    compression: BTreeMap<String, CompressionStats>,
    /// per-variant entropy-band routing telemetry
    routes: BTreeMap<String, RouteStats>,
    rejected: usize,
    /// decode steps executed by the streaming scheduler
    decode_steps: usize,
    /// real session rows across all decode steps
    decode_rows: usize,
    /// latest session-table snapshot: (active sessions, manager counters)
    stream: Option<(usize, StreamStats)>,
    /// latest session-merge gauge: (raw tokens held, tokens after merge)
    stream_tokens: Option<(u64, u64)>,
    faults: FaultCounters,
    /// per `from->to` quarantine-downgrade routing counts
    downgrades: BTreeMap<String, u64>,
    /// latest delivery-monitor snapshot (stream forecast outboxes)
    delivery: Option<DeliveryStats>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::with_obs(&ObsConfig::default())
    }

    /// Metrics whose latency / stage histograms use the `"obs"` config
    /// block's bounds.  Shards must share one config for the per-shard
    /// histograms to merge (enforced by [`Histogram::merge`]).
    pub fn with_obs(cfg: &ObsConfig) -> Metrics {
        Metrics {
            started: Instant::now(),
            served: 0,
            latency: cfg.latency_histogram(),
            batch: Histogram::batch_sizes(),
            stages: (0..Stage::ALL.len()).map(|_| cfg.latency_histogram()).collect(),
            per_variant: BTreeMap::new(),
            compression: BTreeMap::new(),
            routes: BTreeMap::new(),
            rejected: 0,
            decode_steps: 0,
            decode_rows: 0,
            stream: None,
            stream_tokens: None,
            faults: FaultCounters::default(),
            downgrades: BTreeMap::new(),
            delivery: None,
        }
    }

    /// Batch device-call retries beyond the first attempt.
    pub fn record_exec_retries(&mut self, retries: usize) {
        self.faults.exec_retries += retries as u64;
    }

    /// A batch device call exhausted its retries or deadline.
    pub fn record_exec_fault(&mut self) {
        self.faults.exec_faults += 1;
    }

    /// Stream decode-step retries beyond the first attempt.
    pub fn record_step_retries(&mut self, retries: usize) {
        self.faults.step_retries += retries as u64;
    }

    /// A stream decode step exhausted its retries or deadline.
    pub fn record_step_fault(&mut self) {
        self.faults.step_faults += 1;
    }

    /// `n` requests answered with a terminal `DeadlineExceeded`.
    pub fn record_timeouts(&mut self, n: usize) {
        self.faults.timeouts += n as u64;
    }

    /// `n` requests answered with a terminal `Failed`.
    pub fn record_failed(&mut self, n: usize) {
        self.faults.failed += n as u64;
    }

    /// A request was rerouted off a quarantined variant.
    pub fn record_downgrade(&mut self, from: &str, to: &str) {
        self.faults.downgrades += 1;
        *self.downgrades.entry(format!("{from}->{to}")).or_insert(0) += 1;
    }

    pub fn faults(&self) -> FaultCounters {
        self.faults
    }

    /// Latest delivery-monitor counters (stream forecast outboxes).
    pub fn set_delivery(&mut self, stats: DeliveryStats) {
        self.delivery = Some(stats);
    }

    pub fn delivery(&self) -> Option<DeliveryStats> {
        self.delivery
    }

    /// One streaming decode step served `rows` sessions.
    pub fn record_decode_step(&mut self, rows: usize) {
        self.decode_steps += 1;
        self.decode_rows += rows;
    }

    /// Latest session-table snapshot from the `SessionManager`.
    pub fn set_stream(&mut self, active: usize, stats: StreamStats) {
        self.stream = Some((active, stats));
    }

    /// Latest session-table snapshot, if any decode activity recorded one.
    pub fn stream_snapshot(&self) -> Option<(usize, StreamStats)> {
        self.stream
    }

    /// Latest session-merge gauge: raw tokens held across sessions vs
    /// tokens remaining after incremental merging.
    pub fn set_stream_tokens(&mut self, raw: u64, merged: u64) {
        self.stream_tokens = Some((raw, merged));
    }

    pub fn stream_tokens(&self) -> Option<(u64, u64)> {
        self.stream_tokens
    }

    pub fn decode_steps(&self) -> usize {
        self.decode_steps
    }

    pub fn decode_rows(&self) -> usize {
        self.decode_rows
    }

    /// Mean sessions per decode step (streaming batch occupancy).
    pub fn decode_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.decode_rows as f64 / self.decode_steps as f64
    }

    pub fn record_batch(&mut self, variant: &str, batch: usize, latencies: &[f64]) {
        self.batch.record(batch as f64);
        for &l in latencies {
            self.latency.record(l);
        }
        self.served += latencies.len();
        *self.per_variant.entry(variant.to_string()).or_insert(0) += latencies.len();
    }

    /// One merge-pipeline invocation for `variant`: `tokens_in` entered
    /// layer 0, `tokens_out` survived `layers` merge layers.  Recorded
    /// even when merging is bypassed (`tokens_in == tokens_out`,
    /// `layers == 0`) so every serving variant reports a compression
    /// ratio.
    pub fn record_compression(
        &mut self,
        variant: &str,
        tokens_in: usize,
        tokens_out: usize,
        layers: usize,
    ) {
        let c = self.compression.entry(variant.to_string()).or_default();
        c.calls += 1;
        c.tokens_in += tokens_in as u64;
        c.tokens_out += tokens_out as u64;
        c.layers += layers as u64;
    }

    pub fn compression(&self) -> &BTreeMap<String, CompressionStats> {
        &self.compression
    }

    /// The router sent a window with spectral entropy `entropy` to
    /// `variant` (the entropy-band decision, DESIGN.md §7).
    pub fn record_route(&mut self, variant: &str, entropy: f64) {
        let r = self.routes.entry(variant.to_string()).or_default();
        r.decisions += 1;
        r.entropy_sum += entropy;
        r.entropy_min = r.entropy_min.min(entropy);
        r.entropy_max = r.entropy_max.max(entropy);
    }

    pub fn routes(&self) -> &BTreeMap<String, RouteStats> {
        &self.routes
    }

    /// One stage duration in seconds (also stamped into the trace ring by
    /// the serving layers; this is the aggregate view).
    pub fn record_stage(&mut self, stage: Stage, secs: f64) {
        self.stages[stage.idx()].record(secs);
    }

    /// Per-stage duration histograms, indexed by [`Stage::idx`].
    pub fn stage_histograms(&self) -> &[Histogram] {
        &self.stages
    }

    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    pub fn served(&self) -> usize {
        self.served
    }

    pub fn rejected(&self) -> usize {
        self.rejected
    }

    pub fn throughput(&self) -> f64 {
        self.served as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn latency_histogram(&self) -> &Histogram {
        &self.latency
    }

    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        (
            self.latency.percentile(50.0),
            self.latency.percentile(95.0),
            self.latency.percentile(99.0),
        )
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        self.batch.mean()
    }

    pub fn per_variant(&self) -> &BTreeMap<String, usize> {
        &self.per_variant
    }

    /// Heap footprint of the distribution state — constant in the number
    /// of requests served (histograms are fixed-size; the maps grow only
    /// with the variant set).
    pub fn approx_heap_bytes(&self) -> usize {
        self.latency.heap_bytes()
            + self.batch.heap_bytes()
            + self.stages.iter().map(Histogram::heap_bytes).sum::<usize>()
            + (self.per_variant.len()
                + self.compression.len()
                + self.routes.len()
                + self.downgrades.len())
                * std::mem::size_of::<(String, CompressionStats)>()
    }

    pub fn report(&self) -> String {
        let (p50, p95, p99) = self.latency_percentiles();
        let mut s = format!(
            "served={} rejected={} throughput={:.1}/s p50={:.1}ms p95={:.1}ms p99={:.1}ms occupancy={:.2}\n",
            self.served,
            self.rejected,
            self.throughput(),
            p50 * 1e3,
            p95 * 1e3,
            p99 * 1e3,
            self.mean_batch_occupancy(),
        );
        // per-variant serve counts + merge efficiency, over the union of
        // routed and merged variants
        let variants: std::collections::BTreeSet<&String> =
            self.per_variant.keys().chain(self.compression.keys()).collect();
        for v in variants {
            let n = self.per_variant.get(v).copied().unwrap_or(0);
            match self.compression.get(v) {
                Some(c) => s.push_str(&format!(
                    "  {v}: {n} compression={:.2}x (in={} out={} layers={:.0} calls={})\n",
                    c.ratio(),
                    c.tokens_in,
                    c.tokens_out,
                    c.mean_layers(),
                    c.calls,
                )),
                None => s.push_str(&format!("  {v}: {n}\n")),
            }
        }
        for (v, r) in &self.routes {
            s.push_str(&format!(
                "  route {v}: decisions={} entropy_mean={:.3} min={:.3} max={:.3}\n",
                r.decisions,
                r.entropy_mean(),
                r.entropy_min(),
                r.entropy_max(),
            ));
        }
        for (stage, h) in Stage::ALL.iter().zip(&self.stages) {
            if h.is_empty() {
                continue;
            }
            s.push_str(&format!(
                "stage: {} count={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms\n",
                stage.name(),
                h.count(),
                h.mean() * 1e3,
                h.percentile(50.0) * 1e3,
                h.percentile(95.0) * 1e3,
                h.percentile(99.0) * 1e3,
            ));
        }
        if self.decode_steps > 0 || self.stream.is_some() || self.stream_tokens.is_some() {
            s.push_str(&format!(
                "streaming: decode_steps={} rows={} occupancy={:.2}\n",
                self.decode_steps,
                self.decode_rows,
                self.decode_occupancy(),
            ));
            if let Some((active, st)) = &self.stream {
                s.push_str(&format!(
                    "  sessions: active={} admitted={} evicted_lru={} evicted_ttl={} \
                     reroutes={} probes={} points={} requeued={} quarantined={}\n",
                    active,
                    st.admitted,
                    st.evicted_capacity,
                    st.evicted_ttl,
                    st.reroutes,
                    st.probes,
                    st.appended_points,
                    st.requeued_windows,
                    st.quarantined,
                ));
            }
            if let Some((raw, merged)) = self.stream_tokens {
                let ratio = if merged == 0 { 1.0 } else { raw as f64 / merged as f64 };
                s.push_str(&format!(
                    "  merge: raw_tokens={raw} merged_tokens={merged} compression={ratio:.2}x\n",
                ));
            }
        }
        let f = &self.faults;
        if *f != FaultCounters::default() {
            s.push_str(&format!(
                "faults: exec_retries={} exec_faults={} step_retries={} step_faults={} \
                 timeouts={} failed={} downgrades={}\n",
                f.exec_retries,
                f.exec_faults,
                f.step_retries,
                f.step_faults,
                f.timeouts,
                f.failed,
                f.downgrades,
            ));
            for (route, n) in &self.downgrades {
                s.push_str(&format!("  downgrade {route}: {n}\n"));
            }
        }
        if let Some(d) = &self.delivery {
            s.push_str(&format!(
                "delivery: enqueued={} acked={} redelivered={} expired_undelivered={} \
                 dropped_overflow={} pending={}\n",
                d.enqueued,
                d.acked,
                d.redelivered,
                d.expired_undelivered,
                d.dropped_overflow,
                d.pending,
            ));
        }
        // Which ISA the merge kernel dispatched to (DESIGN.md §11) — the
        // observable contract for "is SIMD actually on in this serving
        // process", and what tests/dispatch_env.rs asserts against.
        s.push_str(&format!("kernel: {}\n", crate::merging::simd::dispatch_report()));
        s
    }

    /// This shard's metrics as structured JSON — one element of the wire
    /// `metrics` response ([`merged_json`]); rendered for humans by
    /// `obs::prometheus_text`.
    pub fn to_json(&self, shard: usize) -> Json {
        let mut o = vec![
            ("shard", Json::num(shard as f64)),
            ("served", Json::num(self.served as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("latency", hist_json(&self.latency)),
            (
                "batch",
                Json::obj(vec![
                    ("count", Json::num(self.batch.count() as f64)),
                    ("mean", Json::num(self.batch.mean())),
                ]),
            ),
        ];
        let mut stages = BTreeMap::new();
        for (stage, h) in Stage::ALL.iter().zip(&self.stages) {
            if !h.is_empty() {
                stages.insert(stage.name().to_string(), hist_json(h));
            }
        }
        if !stages.is_empty() {
            o.push(("stages", Json::Obj(stages)));
        }
        let names: std::collections::BTreeSet<&String> =
            self.per_variant.keys().chain(self.compression.keys()).collect();
        let mut variants = BTreeMap::new();
        for v in names {
            let mut b = vec![(
                "served",
                Json::num(self.per_variant.get(v).copied().unwrap_or(0) as f64),
            )];
            if let Some(c) = self.compression.get(v) {
                b.push(("calls", Json::num(c.calls as f64)));
                b.push(("tokens_in", Json::num(c.tokens_in as f64)));
                b.push(("tokens_out", Json::num(c.tokens_out as f64)));
                b.push(("layers", Json::num(c.mean_layers())));
                b.push(("compression", Json::num(c.ratio())));
            }
            variants.insert(v.clone(), Json::obj(b));
        }
        if !variants.is_empty() {
            o.push(("variants", Json::Obj(variants)));
        }
        if !self.routes.is_empty() {
            let mut routes = BTreeMap::new();
            for (v, r) in &self.routes {
                routes.insert(
                    v.clone(),
                    Json::obj(vec![
                        ("decisions", Json::num(r.decisions as f64)),
                        ("entropy_mean", Json::num(r.entropy_mean())),
                        ("entropy_min", Json::num(r.entropy_min())),
                        ("entropy_max", Json::num(r.entropy_max())),
                    ]),
                );
            }
            o.push(("routes", Json::Obj(routes)));
        }
        o.push(("decode_steps", Json::num(self.decode_steps as f64)));
        o.push(("decode_rows", Json::num(self.decode_rows as f64)));
        if self.faults != FaultCounters::default() {
            o.push(("faults", faults_json(&self.faults)));
        }
        if let Some(d) = &self.delivery {
            o.push(("delivery", delivery_json(d)));
        }
        if let Some((raw, merged)) = self.stream_tokens {
            o.push((
                "stream_tokens",
                Json::obj(vec![
                    ("raw", Json::num(raw as f64)),
                    ("merged", Json::num(merged as f64)),
                ]),
            ));
        }
        Json::obj(o)
    }
}

fn hist_json(h: &Histogram) -> Json {
    Json::obj(vec![
        ("count", Json::num(h.count() as f64)),
        ("sum", Json::num(h.sum())),
        ("min", Json::num(h.min())),
        ("max", Json::num(h.max())),
        ("p50", Json::num(h.percentile(50.0))),
        ("p95", Json::num(h.percentile(95.0))),
        ("p99", Json::num(h.percentile(99.0))),
    ])
}

fn faults_json(f: &FaultCounters) -> Json {
    Json::obj(vec![
        ("exec_retries", Json::num(f.exec_retries as f64)),
        ("exec_faults", Json::num(f.exec_faults as f64)),
        ("step_retries", Json::num(f.step_retries as f64)),
        ("step_faults", Json::num(f.step_faults as f64)),
        ("timeouts", Json::num(f.timeouts as f64)),
        ("failed", Json::num(f.failed as f64)),
        ("downgrades", Json::num(f.downgrades as f64)),
    ])
}

fn delivery_json(d: &DeliveryStats) -> Json {
    Json::obj(vec![
        ("enqueued", Json::num(d.enqueued as f64)),
        ("acked", Json::num(d.acked as f64)),
        ("redelivered", Json::num(d.redelivered as f64)),
        ("expired_undelivered", Json::num(d.expired_undelivered as f64)),
        ("dropped_overflow", Json::num(d.dropped_overflow as f64)),
        ("pending", Json::num(d.pending as f64)),
    ])
}

/// Sum two fault-counter snapshots (for the cross-shard roll-up).
fn sum_faults(a: FaultCounters, b: FaultCounters) -> FaultCounters {
    FaultCounters {
        exec_retries: a.exec_retries + b.exec_retries,
        exec_faults: a.exec_faults + b.exec_faults,
        step_retries: a.step_retries + b.step_retries,
        step_faults: a.step_faults + b.step_faults,
        timeouts: a.timeouts + b.timeouts,
        failed: a.failed + b.failed,
        downgrades: a.downgrades + b.downgrades,
    }
}

/// Sum two delivery-ledger snapshots.  Every field is either a monotone
/// count or (`pending`) an instantaneous queue depth, so summation keeps
/// the per-shard ledger identity
/// `enqueued == acked + expired_undelivered + dropped_overflow + pending`
/// intact — pinned by `merged_ledger_identity_survives_summation`.
pub fn sum_delivery(a: DeliveryStats, b: DeliveryStats) -> DeliveryStats {
    DeliveryStats {
        enqueued: a.enqueued + b.enqueued,
        acked: a.acked + b.acked,
        redelivered: a.redelivered + b.redelivered,
        expired_undelivered: a.expired_undelivered + b.expired_undelivered,
        dropped_overflow: a.dropped_overflow + b.dropped_overflow,
        pending: a.pending + b.pending,
    }
}

/// The cross-shard latency histogram: a lossless fold of every shard's
/// latency histogram (`None` only when shard configs disagree on bounds).
fn merged_latency(shards: &[&Metrics]) -> Option<Histogram> {
    let mut it = shards.iter();
    let mut acc = it.next()?.latency.clone();
    for m in it {
        acc.merge(&m.latency).ok()?;
    }
    Some(acc)
}

/// Merge per-shard metrics into one process-level report (DESIGN.md §12):
/// a summary line with cross-shard totals, a merged latency line (the
/// per-shard histograms sum losslessly, so these are true process-level
/// percentiles within the documented 1/32 bucket error), summed fault
/// and delivery counters (ledger identity preserved — see
/// [`sum_delivery`]), then each shard's full [`Metrics::report`]
/// indented under a `shard=<i>` header.
pub fn merged_report(shards: &[&Metrics]) -> String {
    let served: usize = shards.iter().map(|m| m.served()).sum();
    let rejected: usize = shards.iter().map(|m| m.rejected()).sum();
    let decode_steps: usize = shards.iter().map(|m| m.decode_steps()).sum();
    let decode_rows: usize = shards.iter().map(|m| m.decode_rows()).sum();
    let mut s = format!(
        "process: shards={} served={served} rejected={rejected} decode_steps={decode_steps} \
         decode_rows={decode_rows}\n",
        shards.len(),
    );
    if let Some(lat) = merged_latency(shards) {
        if !lat.is_empty() {
            s.push_str(&format!(
                "latency: count={} p50={:.1}ms p95={:.1}ms p99={:.1}ms (merged histograms)\n",
                lat.count(),
                lat.percentile(50.0) * 1e3,
                lat.percentile(95.0) * 1e3,
                lat.percentile(99.0) * 1e3,
            ));
        }
    }
    let faults = shards
        .iter()
        .map(|m| m.faults())
        .fold(FaultCounters::default(), sum_faults);
    if faults != FaultCounters::default() {
        s.push_str(&format!(
            "faults: exec_retries={} exec_faults={} step_retries={} step_faults={} \
             timeouts={} failed={} downgrades={}\n",
            faults.exec_retries,
            faults.exec_faults,
            faults.step_retries,
            faults.step_faults,
            faults.timeouts,
            faults.failed,
            faults.downgrades,
        ));
    }
    if shards.iter().any(|m| m.delivery().is_some()) {
        let d = shards
            .iter()
            .filter_map(|m| m.delivery())
            .fold(DeliveryStats::default(), sum_delivery);
        s.push_str(&format!(
            "delivery: enqueued={} acked={} redelivered={} expired_undelivered={} \
             dropped_overflow={} pending={}\n",
            d.enqueued, d.acked, d.redelivered, d.expired_undelivered, d.dropped_overflow, d.pending,
        ));
    }
    for (i, m) in shards.iter().enumerate() {
        s.push_str(&format!("shard={i}\n"));
        for line in m.report().lines() {
            s.push_str("  ");
            s.push_str(line);
            s.push('\n');
        }
    }
    s
}

/// The structured form of [`merged_report`] — the wire `metrics`
/// response: every shard's [`Metrics::to_json`] plus a `total` block
/// with cross-shard sums and the merged latency histogram.
pub fn merged_json(shards: &[&Metrics]) -> Json {
    let shard_objs: Vec<Json> =
        shards.iter().enumerate().map(|(i, m)| m.to_json(i)).collect();
    let mut total = vec![
        (
            "served",
            Json::num(shards.iter().map(|m| m.served()).sum::<usize>() as f64),
        ),
        (
            "rejected",
            Json::num(shards.iter().map(|m| m.rejected()).sum::<usize>() as f64),
        ),
        (
            "decode_steps",
            Json::num(shards.iter().map(|m| m.decode_steps()).sum::<usize>() as f64),
        ),
        (
            "decode_rows",
            Json::num(shards.iter().map(|m| m.decode_rows()).sum::<usize>() as f64),
        ),
    ];
    if let Some(lat) = merged_latency(shards) {
        total.push(("latency", hist_json(&lat)));
    }
    let faults = shards
        .iter()
        .map(|m| m.faults())
        .fold(FaultCounters::default(), sum_faults);
    if faults != FaultCounters::default() {
        total.push(("faults", faults_json(&faults)));
    }
    if shards.iter().any(|m| m.delivery().is_some()) {
        let d = shards
            .iter()
            .filter_map(|m| m.delivery())
            .fold(DeliveryStats::default(), sum_delivery);
        total.push(("delivery", delivery_json(&d)));
    }
    Json::obj(vec![
        ("shards", Json::arr(shard_objs)),
        ("total", Json::obj(total)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{percentile, Rng};

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new();
        m.record_batch("v1", 4, &[0.010, 0.012, 0.011, 0.013]);
        m.record_batch("v2", 2, &[0.020, 0.022]);
        m.record_rejected();
        assert_eq!(m.served(), 6);
        assert_eq!(m.rejected(), 1);
        assert_eq!(m.per_variant()["v1"], 4);
        assert!((m.mean_batch_occupancy() - 3.0).abs() < 1e-12);
        let (p50, p95, p99) = m.latency_percentiles();
        assert!(p50 <= p95 && p95 <= p99);
        assert!(m.report().contains("v2: 2"));
    }

    #[test]
    fn report_names_the_kernel_isa() {
        let report = Metrics::new().report();
        assert!(report.contains("kernel: isa="), "{report}");
        assert!(report.contains("features="), "{report}");
    }

    #[test]
    fn streaming_section_appears_once_recorded() {
        let mut m = Metrics::new();
        assert!(!m.report().contains("streaming:"));
        m.record_decode_step(3);
        m.record_decode_step(1);
        assert_eq!(m.decode_steps(), 2);
        assert_eq!(m.decode_rows(), 4);
        assert!((m.decode_occupancy() - 2.0).abs() < 1e-12);
        m.set_stream(7, StreamStats { admitted: 9, reroutes: 1, ..StreamStats::default() });
        let report = m.report();
        assert!(report.contains("decode_steps=2"));
        assert!(report.contains("active=7"));
        assert!(report.contains("admitted=9"));
        assert_eq!(m.stream_snapshot().unwrap().0, 7);
    }

    #[test]
    fn fault_and_delivery_sections_appear_once_recorded() {
        let mut m = Metrics::new();
        let clean = m.report();
        assert!(!clean.contains("faults:") && !clean.contains("delivery:"));
        m.record_exec_retries(2);
        m.record_exec_fault();
        m.record_step_retries(1);
        m.record_step_fault();
        m.record_timeouts(3);
        m.record_failed(4);
        m.record_downgrade("v2", "v1");
        m.record_downgrade("v2", "v1");
        let f = m.faults();
        assert_eq!(
            (f.exec_retries, f.exec_faults, f.step_retries, f.step_faults),
            (2, 1, 1, 1)
        );
        assert_eq!((f.timeouts, f.failed, f.downgrades), (3, 4, 2));
        m.set_delivery(DeliveryStats {
            enqueued: 10,
            acked: 6,
            redelivered: 1,
            expired_undelivered: 2,
            dropped_overflow: 0,
            pending: 2,
        });
        let report = m.report();
        assert!(report.contains("faults: exec_retries=2"));
        assert!(report.contains("timeouts=3 failed=4 downgrades=2"));
        assert!(report.contains("downgrade v2->v1: 2"));
        assert!(report.contains("delivery: enqueued=10"));
        assert!(report.contains("expired_undelivered=2"));
        assert!(report.contains("pending=2"));
        assert_eq!(m.delivery().unwrap().acked, 6);
    }

    /// The headline bugfix of the observability PR: `Metrics` used to
    /// keep every latency and batch size in growing `Vec`s.  With the
    /// histograms, heap usage must not move no matter how many requests
    /// are recorded.
    #[test]
    fn memory_is_constant_in_request_count() {
        let mut m = Metrics::new();
        m.record_batch("v1", 4, &[0.010, 0.012, 0.011, 0.013]);
        m.record_stage(Stage::Exec, 0.002);
        m.record_compression("v1", 256, 128, 3);
        m.record_route("v1", 4.0);
        let before = m.approx_heap_bytes();
        for i in 0..10_000usize {
            m.record_batch("v1", 8, &[0.005, 0.007, 0.009, 0.011]);
            m.record_stage(Stage::Exec, 1e-3 * ((i % 7) + 1) as f64);
            m.record_stage(Stage::QueueWait, 1e-4);
            m.record_compression("v1", 512, 256, 3);
            m.record_route("v1", 3.0 + (i % 5) as f64 * 0.1);
        }
        assert_eq!(
            m.approx_heap_bytes(),
            before,
            "Metrics must hold no per-request storage"
        );
        assert_eq!(m.served(), 40_004);
        assert_eq!(m.latency_histogram().count(), 40_004);
    }

    #[test]
    fn compression_stage_route_and_merge_gauge_sections() {
        let mut m = Metrics::new();
        m.record_batch("v1", 2, &[0.010, 0.020]);
        m.record_compression("v1", 768, 384, 3);
        m.record_compression("v1", 768, 384, 3);
        m.record_stage(Stage::Prep, 0.001);
        m.record_stage(Stage::Exec, 0.004);
        m.record_route("v1", 4.2);
        m.record_route("v1", 3.8);
        m.set_stream_tokens(1000, 400);
        let report = m.report();
        assert!(report.contains("v1: 2 compression=2.00x"), "{report}");
        assert!(report.contains("in=1536 out=768 layers=3 calls=2"), "{report}");
        assert!(report.contains("stage: prep"), "{report}");
        assert!(report.contains("stage: exec"), "{report}");
        assert!(report.contains("route v1: decisions=2 entropy_mean=4.000"), "{report}");
        assert!(
            report.contains("merge: raw_tokens=1000 merged_tokens=400 compression=2.50x"),
            "{report}"
        );
        // a variant seen only by the merge pipeline still reports
        m.record_compression("probe", 32, 32, 0);
        assert!(m.report().contains("probe: 0 compression=1.00x"), "{}", m.report());
        let c = m.compression()["v1"];
        assert_eq!((c.calls, c.tokens_in, c.tokens_out), (2, 1536, 768));
        assert!((c.ratio() - 2.0).abs() < 1e-12);
    }

    /// Merged per-shard histograms answer true process-level percentiles
    /// within the documented 1/32 bucket error of the pooled
    /// sorted-vector oracle — the merging contract of the roll-up.
    #[test]
    fn merged_shard_percentiles_within_bound_of_pooled_oracle() {
        let mut rng = Rng::new(11);
        let (mut a, mut b) = (Metrics::new(), Metrics::new());
        let mut all = Vec::new();
        for i in 0..1500usize {
            let v = if i % 2 == 0 {
                0.001 * (1.0 + rng.uniform()) // fast shard: ~1-2ms
            } else {
                0.05 * (1.0 + rng.uniform()) // slow shard: ~50-100ms
            };
            if i % 2 == 0 {
                a.record_batch("v1", 1, &[v]);
            } else {
                b.record_batch("v2", 1, &[v]);
            }
            all.push(v);
        }
        let merged = merged_json(&[&a, &b]);
        let total = merged.req("total").unwrap();
        let lat = total.req("latency").unwrap();
        assert_eq!(lat.req("count").unwrap().as_usize().unwrap(), 1500);
        let sum = lat.req("sum").unwrap().as_f64().unwrap();
        assert!((sum - all.iter().sum::<f64>()).abs() < 1e-9, "sum identity");
        for (p, key) in [(50.0, "p50"), (99.0, "p99")] {
            let oracle = percentile(&mut all, p);
            let got = lat.req(key).unwrap().as_f64().unwrap();
            let rel = (got - oracle).abs() / oracle;
            assert!(rel <= 1.0 / 32.0 + 1e-12, "{key}: {got} vs oracle {oracle}");
        }
        let report = merged_report(&[&a, &b]);
        assert!(report.contains("latency: count=1500"), "{report}");
        assert!(report.contains("(merged histograms)"), "{report}");
    }

    #[test]
    fn shard_json_exposes_the_full_schema() {
        let mut m = Metrics::new();
        m.record_batch("v1", 3, &[0.010, 0.011, 0.012]);
        m.record_stage(Stage::Exec, 0.004);
        m.record_compression("v1", 96, 48, 2);
        m.record_route("v1", 4.5);
        m.record_exec_fault();
        m.set_delivery(DeliveryStats { enqueued: 2, pending: 2, ..DeliveryStats::default() });
        m.set_stream_tokens(128, 64);
        let j = m.to_json(3);
        assert_eq!(j.req("shard").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.req("served").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.req("latency").unwrap().req("count").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.req("batch").unwrap().req("mean").unwrap().as_f64().unwrap(), 3.0);
        let exec = j.req("stages").unwrap().req("exec").unwrap();
        assert_eq!(exec.req("count").unwrap().as_usize().unwrap(), 1);
        let v1 = j.req("variants").unwrap().req("v1").unwrap();
        assert_eq!(v1.req("tokens_in").unwrap().as_usize().unwrap(), 96);
        assert!((v1.req("compression").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-12);
        let route = j.req("routes").unwrap().req("v1").unwrap();
        assert_eq!(route.req("decisions").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            j.req("faults").unwrap().req("exec_faults").unwrap().as_usize().unwrap(),
            1
        );
        assert_eq!(
            j.req("delivery").unwrap().req("pending").unwrap().as_usize().unwrap(),
            2
        );
        assert_eq!(
            j.req("stream_tokens").unwrap().req("merged").unwrap().as_usize().unwrap(),
            64
        );
        // the JSON round-trips through the wire encoding
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    fn balanced(
        enqueued: u64,
        acked: u64,
        redelivered: u64,
        expired: u64,
        dropped: u64,
    ) -> DeliveryStats {
        let stats = DeliveryStats {
            enqueued,
            acked,
            redelivered,
            expired_undelivered: expired,
            dropped_overflow: dropped,
            pending: enqueued - acked - expired - dropped,
        };
        assert_eq!(
            stats.enqueued,
            stats.acked + stats.expired_undelivered + stats.dropped_overflow + stats.pending,
            "test fixture must balance"
        );
        stats
    }

    /// The satellite contract for the cross-shard roll-up: summing
    /// per-shard ledgers (each individually balanced) yields a ledger
    /// that still satisfies
    /// `enqueued == acked + expired_undelivered + dropped_overflow + pending`.
    #[test]
    fn merged_ledger_identity_survives_summation() {
        let mut a = Metrics::new();
        a.record_batch("v1", 2, &[0.010, 0.011]);
        a.set_delivery(balanced(10, 4, 1, 2, 1));
        let mut b = Metrics::new();
        b.record_batch("v2", 1, &[0.020]);
        b.record_rejected();
        b.record_failed(1);
        b.set_delivery(balanced(7, 7, 0, 0, 0));
        let c = Metrics::new(); // idle shard: no delivery snapshot at all
        let merged = sum_delivery(a.delivery().unwrap(), b.delivery().unwrap());
        assert_eq!(
            merged.enqueued,
            merged.acked + merged.expired_undelivered + merged.dropped_overflow + merged.pending,
            "ledger identity must survive summation: {merged:?}"
        );
        assert_eq!((merged.enqueued, merged.acked, merged.pending), (17, 11, 3));
        let report = merged_report(&[&a, &b, &c]);
        assert!(report.contains("process: shards=3 served=3 rejected=1"), "{report}");
        assert!(report.contains("delivery: enqueued=17"), "{report}");
        assert!(report.contains("pending=3"), "{report}");
        assert!(report.contains("faults: ") && report.contains("failed=1"), "{report}");
        for i in 0..3 {
            assert!(report.contains(&format!("shard={i}\n")), "{report}");
        }
        // per-shard sections are indented copies of each shard's report
        assert!(report.contains("  served=2 "), "{report}");
        assert!(report.contains("  served=1 "), "{report}");
        assert!(report.contains("  served=0 "), "{report}");
        // and the structured form agrees on the totals
        let j = merged_json(&[&a, &b, &c]);
        assert_eq!(j.req("shards").unwrap().as_arr().unwrap().len(), 3);
        let total = j.req("total").unwrap();
        assert_eq!(total.req("served").unwrap().as_usize().unwrap(), 3);
        assert_eq!(
            total.req("delivery").unwrap().req("enqueued").unwrap().as_usize().unwrap(),
            17
        );
    }
}
