//! Serving metrics: latency distribution, throughput, batch occupancy,
//! per-variant routing counts, and session-level streaming counters.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::streaming::StreamStats;
use crate::util::percentile;

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    latencies: Vec<f64>,
    batch_sizes: Vec<usize>,
    per_variant: BTreeMap<String, usize>,
    rejected: usize,
    /// decode steps executed by the streaming scheduler
    decode_steps: usize,
    /// real session rows across all decode steps
    decode_rows: usize,
    /// latest session-table snapshot: (active sessions, manager counters)
    stream: Option<(usize, StreamStats)>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            latencies: Vec::new(),
            batch_sizes: Vec::new(),
            per_variant: BTreeMap::new(),
            rejected: 0,
            decode_steps: 0,
            decode_rows: 0,
            stream: None,
        }
    }

    /// One streaming decode step served `rows` sessions.
    pub fn record_decode_step(&mut self, rows: usize) {
        self.decode_steps += 1;
        self.decode_rows += rows;
    }

    /// Latest session-table snapshot from the `SessionManager`.
    pub fn set_stream(&mut self, active: usize, stats: StreamStats) {
        self.stream = Some((active, stats));
    }

    pub fn decode_steps(&self) -> usize {
        self.decode_steps
    }

    pub fn decode_rows(&self) -> usize {
        self.decode_rows
    }

    /// Mean sessions per decode step (streaming batch occupancy).
    pub fn decode_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.decode_rows as f64 / self.decode_steps as f64
    }

    pub fn record_batch(&mut self, variant: &str, batch: usize, latencies: &[f64]) {
        self.batch_sizes.push(batch);
        self.latencies.extend_from_slice(latencies);
        *self.per_variant.entry(variant.to_string()).or_insert(0) += latencies.len();
    }

    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    pub fn served(&self) -> usize {
        self.latencies.len()
    }

    pub fn rejected(&self) -> usize {
        self.rejected
    }

    pub fn throughput(&self) -> f64 {
        self.served() as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let mut l = self.latencies.clone();
        (
            percentile(&mut l, 50.0),
            percentile(&mut l, 95.0),
            percentile(&mut l, 99.0),
        )
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn per_variant(&self) -> &BTreeMap<String, usize> {
        &self.per_variant
    }

    pub fn report(&self) -> String {
        let (p50, p95, p99) = self.latency_percentiles();
        let mut s = format!(
            "served={} rejected={} throughput={:.1}/s p50={:.1}ms p95={:.1}ms p99={:.1}ms occupancy={:.2}\n",
            self.served(),
            self.rejected,
            self.throughput(),
            p50 * 1e3,
            p95 * 1e3,
            p99 * 1e3,
            self.mean_batch_occupancy(),
        );
        for (v, n) in &self.per_variant {
            s.push_str(&format!("  {v}: {n}\n"));
        }
        if self.decode_steps > 0 || self.stream.is_some() {
            s.push_str(&format!(
                "streaming: decode_steps={} rows={} occupancy={:.2}\n",
                self.decode_steps,
                self.decode_rows,
                self.decode_occupancy(),
            ));
            if let Some((active, st)) = &self.stream {
                s.push_str(&format!(
                    "  sessions: active={} admitted={} evicted_lru={} evicted_ttl={} \
                     reroutes={} probes={} points={}\n",
                    active,
                    st.admitted,
                    st.evicted_capacity,
                    st.evicted_ttl,
                    st.reroutes,
                    st.probes,
                    st.appended_points,
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new();
        m.record_batch("v1", 4, &[0.010, 0.012, 0.011, 0.013]);
        m.record_batch("v2", 2, &[0.020, 0.022]);
        m.record_rejected();
        assert_eq!(m.served(), 6);
        assert_eq!(m.rejected(), 1);
        assert_eq!(m.per_variant()["v1"], 4);
        assert!((m.mean_batch_occupancy() - 3.0).abs() < 1e-12);
        let (p50, p95, p99) = m.latency_percentiles();
        assert!(p50 <= p95 && p95 <= p99);
        assert!(m.report().contains("v2: 2"));
    }

    #[test]
    fn streaming_section_appears_once_recorded() {
        let mut m = Metrics::new();
        assert!(!m.report().contains("streaming:"));
        m.record_decode_step(3);
        m.record_decode_step(1);
        assert_eq!(m.decode_steps(), 2);
        assert_eq!(m.decode_rows(), 4);
        assert!((m.decode_occupancy() - 2.0).abs() < 1e-12);
        m.set_stream(7, StreamStats { admitted: 9, reroutes: 1, ..StreamStats::default() });
        let report = m.report();
        assert!(report.contains("decode_steps=2"));
        assert!(report.contains("active=7"));
        assert!(report.contains("admitted=9"));
    }
}
