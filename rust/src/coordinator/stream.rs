//! Streaming decode scheduler: continuous batching of ready sessions
//! into the staged serving pipeline (DESIGN.md §9).
//!
//! The batch path (`pipeline::run_stages`) overlaps host prep with device
//! execution for one-shot requests.  This module is its streaming twin:
//!
//! ```text
//!  append events      stream-prep thread (this module)     execute stage
//!  (clients)  ──────► SessionManager: O(n) incremental ──► model.execute +
//!                     merge per append; decode steps   ▲   deliver rolling
//!                     batch ready sessions FIFO-fair,  │   forecasts
//!                     slab filled on the WorkerPool    │
//!                        ▲      │ ready (depth 1)      │
//!                        └──────┴──── slab recycle ────┘
//!                             (2 slab pairs in flight)
//! ```
//!
//! * Appends are absorbed continuously; each costs O(points) against the
//!   session's incremental causal merge state — never a recompute.
//! * A **decode step** batches up to `capacity` ready sessions (FIFO by
//!   oldest unserved data, so a hot session cannot starve a quiet one),
//!   assembles the `(capacity, m·d)` merged-context slab **in parallel on
//!   the shared [`WorkerPool`]** (one task per row), and hands it to the
//!   execute closure through a depth-1 channel with recycled buffers —
//!   the same double-buffered merge-while-execute shape as the batch
//!   pipeline, so slab assembly for step N+1 overlaps step N's device
//!   time.
//! * Sessions at different fill levels share a batch: short sessions are
//!   edge-padded in the value slab and carry **size 0** in the parallel
//!   size slab ([`DecodeStep::sizes`]), the size-array form the merge
//!   kernels already speak, so a size-aware artifact can mask padding.
//!
//! Like `pipeline::run_stages`, everything here is PJRT-free and generic
//! over the device closure: `tomers stream`, the streaming bench and the
//! tests drive the identical machinery with a synthetic device.

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::faults::{call_with_retry, FaultPolicy};
use super::metrics::Metrics;
use super::pipeline::VariantMeta;
use crate::obs::{recorder, Stage};
use crate::runtime::pool::WorkerPool;
use crate::streaming::{SessionManager, StreamingConfig};
use crate::util::{join_annotated, lock_ignore_poison as lock};

/// One client-side event of a stream intake.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// Observations for a session (admitted on first sight — the
    /// admission probe derives its merge spec from these points).
    /// `points` is a whole number of `d`-channel interleaved frames for
    /// the manager's configured `d` (ragged lengths are rejected at
    /// intake — the homogeneous-`d` design, DESIGN.md §9).
    Append { session: u64, points: Vec<f32> },
}

/// One assembled decode step: `rows` ready sessions sharing a
/// `(capacity, m, d)` slab.
pub struct DecodeStep {
    /// session ids, one per real row
    pub sessions: Vec<u64>,
    /// `(capacity, m * d)` merged-context values (interleaved channels);
    /// short batches repeat the last real row (the batch pipeline's
    /// padding convention)
    pub slab: Vec<f32>,
    /// `(capacity, m)` per-token sizes; 0 marks padding (both within-row
    /// front padding and whole padding rows)
    pub sizes: Vec<f32>,
    /// real rows
    pub rows: usize,
    /// channels per token of this step's slab rows
    pub d: usize,
    /// per-row real-token fill (diagnostics: batch share of sessions
    /// still shorter than m)
    pub fills: Vec<usize>,
    /// set by the execute stage when this step's device call exhausted
    /// its retries: the recycle path doubles as the fault-feedback path —
    /// the prep thread re-enqueues the step's sessions' windows (or
    /// quarantines repeat offenders) when it harvests the buffer
    pub faulted: bool,
}

impl DecodeStep {
    /// An empty recyclable step buffer.
    pub fn empty() -> DecodeStep {
        DecodeStep {
            sessions: Vec::new(),
            slab: Vec::new(),
            sizes: Vec::new(),
            rows: 0,
            d: 1,
            fills: Vec::new(),
            faulted: false,
        }
    }
}

/// Number of slab pairs in flight between the stream-prep thread and the
/// execute stage (mirrors `pipeline::SLAB_BUFFERS`).
pub const STREAM_SLAB_BUFFERS: usize = 2;

/// How long the prep thread blocks for one event before re-checking
/// deadlines/readiness.
const EVENT_POLL: Duration = Duration::from_millis(2);

/// Partial-batch flush deadline: a ready session waits at most this long
/// for the batch to fill before a short decode step is emitted anyway.
/// Without it, sustained sub-capacity traffic would defer partial
/// batches forever — the same flush-starvation class the batch intake
/// fixed with deadline-ordered `drain_ready` (matches its default
/// `max_wait` of 20ms).
const DECODE_MAX_WAIT: Duration = Duration::from_millis(20);

/// Builds decode steps from a [`SessionManager`] — separable from the
/// threaded loop so tests and benches can drive single steps
/// deterministically.
pub struct StreamScheduler {
    meta: VariantMeta,
    manager: SessionManager,
    ready: Vec<u64>,
}

impl StreamScheduler {
    pub fn new(meta: VariantMeta, cfg: StreamingConfig) -> Result<StreamScheduler> {
        Ok(StreamScheduler { meta, manager: SessionManager::new(cfg)?, ready: Vec::new() })
    }

    pub fn manager(&self) -> &SessionManager {
        &self.manager
    }

    pub fn manager_mut(&mut self) -> &mut SessionManager {
        &mut self.manager
    }

    /// Apply one intake event (admit-on-first-sight append).
    pub fn apply(&mut self, event: StreamEvent, now: Instant) -> Result<()> {
        match event {
            StreamEvent::Append { session, points } => {
                self.manager.append(session, &points, now)?;
            }
        }
        Ok(())
    }

    /// Number of decode-ready sessions right now (count only — the
    /// FIFO ordering work happens once, inside [`Self::step_into`]).
    pub fn ready_len(&self) -> usize {
        self.manager.ready_count()
    }

    /// Assemble the next decode step into recycled buffers: up to
    /// `capacity` ready sessions FIFO-fair, slab rows (`m * d` values
    /// each, one size per token) filled in parallel on `pool`, sessions
    /// marked served.  Returns the real row count (0 = nothing ready;
    /// `step` untouched beyond its buffers).
    pub fn step_into(&mut self, pool: &WorkerPool, now: Instant, step: &mut DecodeStep) -> usize {
        let (capacity, m) = (self.meta.capacity, self.meta.m);
        let d = self.manager.config().d;
        let row_len = m * d;
        self.manager.take_ready(capacity, &mut self.ready);
        let rows = self.ready.len();
        if rows == 0 {
            return 0;
        }
        step.sessions.clear();
        step.sessions.extend_from_slice(&self.ready);
        step.rows = rows;
        step.d = d;
        step.slab.clear();
        step.slab.resize(capacity * row_len, 0.0);
        step.sizes.clear();
        step.sizes.resize(capacity * m, 0.0);
        step.fills.clear();
        step.fills.resize(rows, 0);
        {
            let mgr = &self.manager;
            let tasks: Vec<_> = step
                .sessions
                .iter()
                .zip(step.slab.chunks_mut(row_len))
                .zip(step.sizes.chunks_mut(m))
                .zip(step.fills.iter_mut())
                .map(|(((&id, row), size_row), fill)| {
                    move || {
                        *fill = mgr.context_fill(id, row, size_row);
                    }
                })
                .collect();
            pool.run(tasks);
        }
        // pad short batches by repeating the last real row (values only —
        // padding rows keep size 0)
        for p in rows..capacity {
            step.slab.copy_within((rows - 1) * row_len..rows * row_len, p * row_len);
        }
        self.manager.mark_decoded(&step.sessions, now);
        rows
    }
}

/// The spawned half of the streaming pipeline: the prep thread's handle
/// plus the recycle channel the execute side returns step buffers
/// through.  Produced by [`spawn_stream_prep`].
pub struct StreamPrepStage {
    /// send executed steps back for buffer recycling
    pub recycle: Sender<DecodeStep>,
    /// the stream-prep thread (exits when the event channel closes or the
    /// ready channel is dropped)
    pub join: thread::JoinHandle<()>,
}

/// Spawn the stream-prep thread: it owns the sessions, absorbs events,
/// and sends assembled decode steps through `ready_tx` (mapped by `wrap`,
/// so the batch and stream pipelines can share one ready channel — see
/// [`super::serve_loop::run_serve_stages`]).  [`run_stream_stages`] is
/// the single-pipeline composition of this plus an execute loop.
///
/// Decode cadence: a step is emitted as soon as `capacity` sessions are
/// ready, or — once the intake has drained every pending event — for
/// whatever is ready (partial batches flush rather than wait for load),
/// with a `DECODE_MAX_WAIT` (20 ms) deadline so sustained sub-capacity
/// traffic cannot starve partial batches.  On event-channel close,
/// remaining ready sessions are flushed before the thread exits.
///
/// Fault feedback (DESIGN.md §10): recycled step buffers carry
/// [`DecodeStep::faulted`]; on harvest the prep thread restores the
/// step's sessions' consumed windows via
/// [`SessionManager::requeue_after_fault`] — so a faulted window is
/// retried on the next step instead of dropped — quarantining sessions
/// past `faults.session_fault_budget`.  (A step still in flight at
/// shutdown cannot be harvested; its window is lost with the pipeline.)
// One arg over clippy's limit: stage wiring (channels + wrap), shared
// metrics and the fault policy are each irreducible here.
#[allow(clippy::too_many_arguments)]
pub fn spawn_stream_prep<T, W>(
    events: Receiver<StreamEvent>,
    meta: VariantMeta,
    cfg: StreamingConfig,
    pool: &'static WorkerPool,
    metrics: Arc<Mutex<Metrics>>,
    faults: FaultPolicy,
    ready_tx: SyncSender<T>,
    wrap: W,
) -> Result<StreamPrepStage>
where
    T: Send + 'static,
    W: Fn(DecodeStep) -> T + Send + 'static,
{
    faults.validate()?;
    let mut scheduler = StreamScheduler::new(meta.clone(), cfg)?;
    let (slab_tx, slab_rx) = std::sync::mpsc::channel::<DecodeStep>();
    for _ in 0..STREAM_SLAB_BUFFERS {
        let _ = slab_tx.send(DecodeStep::empty());
    }
    let join = thread::Builder::new()
        .name("tomers-stream-prep".into())
        .spawn(move || {
            let budget = faults.session_fault_budget;
            // step buffers harvested off the recycle channel, ready for
            // reuse (fault flags already processed)
            let mut free: Vec<DecodeStep> = Vec::new();
            let mut open = true;
            while open {
                // absorb events: block briefly for the first, drain the rest
                let mut drained = match events.recv_timeout(EVENT_POLL) {
                    Ok(ev) => {
                        if let Err(e) = scheduler.apply(ev, Instant::now()) {
                            eprintln!("stream intake: {e:#}");
                        }
                        true
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => false,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                        false
                    }
                };
                while let Ok(ev) = events.try_recv() {
                    drained = true;
                    if let Err(e) = scheduler.apply(ev, Instant::now()) {
                        eprintln!("stream intake: {e:#}");
                    }
                }
                // harvest recycled buffers eagerly, even when no step will
                // be emitted: a faulted step's sessions only become ready
                // again once their windows are restored here
                while let Ok(mut step) = slab_rx.try_recv() {
                    harvest_step(&mut scheduler, &mut step, budget, &metrics);
                    free.push(step);
                }
                scheduler.manager_mut().evict_expired(Instant::now());
                // emit: full batches always; partial batches once the
                // intake is idle (nothing drained), the oldest ready
                // session is past the flush deadline, or on shutdown
                loop {
                    let now = Instant::now();
                    let ready = scheduler.ready_len();
                    if ready == 0 {
                        break;
                    }
                    if drained && ready < meta.capacity && open {
                        let overdue = scheduler
                            .manager()
                            .oldest_ready_at()
                            .is_some_and(|t| now.duration_since(t) >= DECODE_MAX_WAIT);
                        if !overdue {
                            break;
                        }
                    }
                    let mut step = match free.pop() {
                        Some(s) => s,
                        None => match slab_rx.recv() {
                            Ok(mut s) => {
                                harvest_step(&mut scheduler, &mut s, budget, &metrics);
                                s
                            }
                            Err(_) => return, // execute stage gone
                        },
                    };
                    let rows = scheduler.step_into(pool, now, &mut step);
                    if rows == 0 {
                        free.push(step);
                        break;
                    }
                    let prep_dur = now.elapsed();
                    let leader = step.sessions.first().copied().unwrap_or(0);
                    recorder().record(leader, Stage::StreamPrep, 0, now, prep_dur, rows as u32);
                    {
                        let mut mx = lock(&metrics);
                        mx.record_stage(Stage::StreamPrep, prep_dur.as_secs_f64());
                        mx.record_decode_step(rows);
                        mx.set_stream(scheduler.manager().len(), scheduler.manager().stats());
                        let (raw, merged) = scheduler.manager().merge_totals();
                        mx.set_stream_tokens(raw, merged);
                    }
                    if ready_tx.send(wrap(step)).is_err() {
                        return;
                    }
                }
            }
        })
        .map_err(|e| anyhow!("spawning stream-prep thread: {e}"))?;
    Ok(StreamPrepStage { recycle: slab_tx, join })
}

/// Process a harvested step buffer's fault feedback.  Faulted: restore
/// its sessions' consumed windows for the next decode step, quarantining
/// sessions past their fault budget, and refresh the streaming metrics
/// snapshot so the requeue/quarantine counters are visible without
/// another decode step.  Clean: reset the sessions' consecutive-fault
/// counts — success must be confirmed from the harvest, not assumed at
/// assembly, or an always-faulting session would never hit its budget.
/// Zeroes `rows` either way so a buffer is processed exactly once.
fn harvest_step(
    scheduler: &mut StreamScheduler,
    step: &mut DecodeStep,
    budget: u32,
    metrics: &Mutex<Metrics>,
) {
    let ids = &step.sessions[..step.rows];
    if step.faulted {
        step.faulted = false;
        let now = Instant::now();
        let (_requeued, quarantined) =
            scheduler.manager_mut().requeue_after_fault(ids, budget, now);
        if quarantined > 0 {
            eprintln!(
                "stream: {quarantined} session(s) quarantined after {budget} consecutive \
                 decode faults"
            );
        }
        let mut mx = lock(metrics);
        mx.set_stream(scheduler.manager().len(), scheduler.manager().stats());
    } else if step.rows > 0 {
        scheduler.manager_mut().decode_succeeded(ids);
    }
    step.rows = 0;
}

/// Execute one decode step and deliver each session's rolling forecast —
/// the execute-stage body shared by [`run_stream_stages`] and the dual
/// serving loop.  The device call is retried with the policy's backoff
/// inside `faults.step_deadline`; an exhausted step is marked
/// [`DecodeStep::faulted`] so the recycle path re-enqueues its sessions'
/// windows (see [`spawn_stream_prep`]) instead of dropping them.  The
/// caller recycles `step` afterwards either way.
pub(crate) fn execute_and_deliver<X, S>(
    execute: &mut X,
    deliver: &mut S,
    step: &mut DecodeStep,
    faults: &FaultPolicy,
    metrics: &Mutex<Metrics>,
) where
    X: FnMut(&mut DecodeStep) -> Result<Vec<Vec<f32>>>,
    S: FnMut(u64, Vec<f32>),
{
    let t_exec = Instant::now();
    let deadline = faults.step_deadline.map(|d| t_exec + d);
    let out = call_with_retry(faults, deadline, "stream decode step", || execute(step));
    let exec_dur = t_exec.elapsed();
    let leader = step.sessions.first().copied().unwrap_or(0);
    recorder().record(leader, Stage::StreamExec, 0, t_exec, exec_dur, out.attempts as u32);
    {
        let mut mx = lock(metrics);
        mx.record_stage(Stage::StreamExec, exec_dur.as_secs_f64());
        if out.attempts > 1 {
            mx.record_step_retries(out.attempts - 1);
        }
    }
    match out.result {
        Ok(forecasts) if forecasts.len() >= step.rows => {
            let t_del = Instant::now();
            for (id, forecast) in step.sessions.iter().zip(forecasts) {
                deliver(*id, forecast);
            }
            let del_dur = t_del.elapsed();
            recorder().record(leader, Stage::Deliver, 0, t_del, del_dur, step.rows as u32);
            lock(metrics).record_stage(Stage::Deliver, del_dur.as_secs_f64());
        }
        Ok(forecasts) => {
            eprintln!(
                "stream execute returned {} rows for {} sessions — re-enqueuing the step's \
                 windows",
                forecasts.len(),
                step.rows
            );
            lock(metrics).record_step_fault();
            step.faulted = true;
        }
        Err(e) => {
            eprintln!(
                "stream decode step failed{}: {e:#}",
                if out.timed_out { " (step deadline)" } else { "" }
            );
            lock(metrics).record_step_fault();
            step.faulted = true;
        }
    }
}

/// Run the streaming intake + decode stages until the event channel
/// closes, mirroring [`super::pipeline::run_stages`]'s topology: a prep
/// thread ([`spawn_stream_prep`]) owns the sessions and assembles steps,
/// the **calling thread** runs `execute` (PJRT handles are not `Send`)
/// and delivers each session's rolling forecast through `deliver`.
/// `tomers serve` uses [`super::serve_loop::run_serve_stages`] instead,
/// which multiplexes these stages with the batch pipeline on one device
/// thread.
// One arg over clippy's limit: the fault policy joined an already-full
// stage signature (see `spawn_stream_prep`).
#[allow(clippy::too_many_arguments)]
pub fn run_stream_stages<X, S>(
    events: Receiver<StreamEvent>,
    meta: VariantMeta,
    cfg: StreamingConfig,
    pool: &'static WorkerPool,
    metrics: Arc<Mutex<Metrics>>,
    faults: FaultPolicy,
    mut execute: X,
    mut deliver: S,
) -> Result<()>
where
    X: FnMut(&mut DecodeStep) -> Result<Vec<Vec<f32>>>,
    S: FnMut(u64, Vec<f32>),
{
    let (ready_tx, ready_rx) = sync_channel::<DecodeStep>(1);
    let prep = spawn_stream_prep(
        events,
        meta,
        cfg,
        pool,
        Arc::clone(&metrics),
        faults.clone(),
        ready_tx,
        |s| s,
    )?;
    for mut step in ready_rx.iter() {
        execute_and_deliver(&mut execute, &mut deliver, &mut step, &faults, &metrics);
        let _ = prep.recycle.send(step);
    }
    drop(prep.recycle);
    join_annotated(prep.join, "stream-prep thread")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::streaming::StreamPolicy;
    use crate::util::Rng;

    fn test_cfg() -> StreamingConfig {
        StreamingConfig {
            max_sessions: 16,
            session_ttl: Duration::from_secs(3600),
            reprobe_every: 10_000,
            raw_window: 64,
            max_merged: 256,
            min_new: 4,
            policy: StreamPolicy::default(),
            ..StreamingConfig::default()
        }
    }

    #[test]
    fn step_batches_ready_sessions_and_pads() {
        let pool = WorkerPool::new(2);
        let meta = VariantMeta { capacity: 4, m: 8 };
        let mut sched = StreamScheduler::new(meta, test_cfg()).unwrap();
        let now = Instant::now();
        let mut rng = Rng::new(3);
        // two ready sessions (>= min_new points), one not ready
        for id in [1u64, 2] {
            let pts: Vec<f32> = (0..6).map(|_| rng.normal() as f32).collect();
            sched.apply(StreamEvent::Append { session: id, points: pts }, now).unwrap();
        }
        sched.apply(StreamEvent::Append { session: 3, points: vec![1.0] }, now).unwrap();
        let mut step = DecodeStep::empty();
        let rows = sched.step_into(&pool, now, &mut step);
        assert_eq!(rows, 2);
        assert_eq!(step.d, 1);
        assert_eq!(step.sessions, vec![1, 2]);
        assert_eq!(step.slab.len(), 4 * 8);
        assert_eq!(step.sizes.len(), 4 * 8);
        // padding rows repeat the last real row's values but carry size 0
        assert_eq!(step.slab[2 * 8..3 * 8], step.slab[8..16]);
        assert!(step.sizes[2 * 8..].iter().all(|&s| s == 0.0));
        // within-row: 6 points (threshold may have merged some) fill < m,
        // sizes nonzero exactly on the fill
        for r in 0..rows {
            let fill = step.fills[r];
            assert!(fill > 0 && fill <= 8);
            let sz = &step.sizes[r * 8..(r + 1) * 8];
            assert!(sz[..8 - fill].iter().all(|&s| s == 0.0));
            assert!(sz[8 - fill..].iter().all(|&s| s > 0.0));
        }
        // the step marked sessions served: nothing ready now
        assert_eq!(sched.ready_len(), 0);
    }

    /// Multivariate decode steps: the slab row is `m * d` interleaved
    /// values with one size per token, homogeneous `d` across the batch.
    #[test]
    fn step_assembles_multivariate_rows() {
        let pool = WorkerPool::new(2);
        let (capacity, m, d) = (3usize, 8usize, 2usize);
        let meta = VariantMeta { capacity, m };
        let cfg = StreamingConfig { d, ..test_cfg() };
        let mut sched = StreamScheduler::new(meta, cfg).unwrap();
        let now = Instant::now();
        let mut rng = Rng::new(5);
        for id in [1u64, 2] {
            // 6 frames x 2 channels
            let pts: Vec<f32> = (0..6 * d).map(|_| rng.normal() as f32).collect();
            sched.apply(StreamEvent::Append { session: id, points: pts }, now).unwrap();
        }
        let mut step = DecodeStep::empty();
        let rows = sched.step_into(&pool, now, &mut step);
        assert_eq!(rows, 2);
        assert_eq!(step.d, d);
        assert_eq!(step.slab.len(), capacity * m * d, "values are (capacity, m*d)");
        assert_eq!(step.sizes.len(), capacity * m, "sizes stay per token");
        // padding rows repeat the last real row's m*d values, size 0
        assert_eq!(step.slab[2 * m * d..3 * m * d], step.slab[m * d..2 * m * d]);
        assert!(step.sizes[2 * m..].iter().all(|&s| s == 0.0));
        for r in 0..rows {
            let fill = step.fills[r];
            assert!(fill > 0 && fill <= m);
            let sz = &step.sizes[r * m..(r + 1) * m];
            assert!(sz[..m - fill].iter().all(|&s| s == 0.0));
            assert!(sz[m - fill..].iter().all(|&s| s > 0.0));
        }
        // a ragged append (5 scalars against d = 2) errors through apply
        let err = sched
            .apply(StreamEvent::Append { session: 9, points: vec![0.0; 5] }, now)
            .unwrap_err();
        assert!(err.to_string().contains("2-channel"), "{err}");
    }

    #[test]
    fn stages_deliver_rolling_forecasts() {
        let pool = WorkerPool::global();
        let meta = VariantMeta { capacity: 2, m: 16 };
        let (tx, rx) = std::sync::mpsc::channel();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let mut rng = Rng::new(9);
        for round in 0..3 {
            for id in 0..5u64 {
                let pts: Vec<f32> = (0..4 + (round as usize % 2))
                    .map(|_| rng.normal() as f32)
                    .collect();
                tx.send(StreamEvent::Append { session: id, points: pts }).unwrap();
            }
        }
        drop(tx);
        let delivered = Arc::new(Mutex::new(Vec::<(u64, usize)>::new()));
        let sink = Arc::clone(&delivered);
        run_stream_stages(
            rx,
            meta,
            test_cfg(),
            pool,
            Arc::clone(&metrics),
            FaultPolicy::default(),
            |step| {
                assert_eq!(step.slab.len(), 2 * 16);
                Ok(vec![vec![0.5f32; 4]; step.rows])
            },
            move |id, forecast| lock(&sink).push((id, forecast.len())),
        )
        .unwrap();
        let got = lock(&delivered);
        // every session appended >= min_new points, so each was decoded
        // at least once before shutdown flushed the ready set
        for id in 0..5u64 {
            assert!(got.iter().any(|&(s, _)| s == id), "session {id} never decoded");
        }
        assert!(got.iter().all(|&(_, n)| n == 4));
        let mx = lock(&metrics);
        assert!(mx.decode_steps() >= 3, "5 sessions / capacity 2 needs >= 3 steps");
        assert_eq!(mx.decode_rows(), got.len());
    }

    /// Regression (flush starvation): with sustained sub-capacity
    /// traffic, `drained` is true on almost every poll iteration, and
    /// before the decode deadline existed partial batches deferred
    /// forever — ready sessions got no forecasts until shutdown.  The
    /// deadline must produce decode steps *while* events keep arriving.
    #[test]
    fn partial_batches_flush_under_sustained_traffic() {
        let pool = WorkerPool::global();
        let meta = VariantMeta { capacity: 64, m: 8 };
        let (tx, rx) = std::sync::mpsc::channel();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let cfg = StreamingConfig { min_new: 1, ..test_cfg() };
        let feeder = std::thread::spawn(move || {
            // ~150ms of continuous 2-session traffic (never fills 64)
            for _ in 0..75 {
                for id in 0..2u64 {
                    let ev = StreamEvent::Append { session: id, points: vec![1.0, 2.0] };
                    if tx.send(ev).is_err() {
                        return;
                    }
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        });
        let delivered = Arc::new(Mutex::new(0usize));
        let sink = Arc::clone(&delivered);
        run_stream_stages(
            rx,
            meta,
            cfg,
            pool,
            Arc::clone(&metrics),
            FaultPolicy::default(),
            |step| Ok(vec![Vec::new(); step.rows]),
            move |_, _| *lock(&sink) += 1,
        )
        .unwrap();
        feeder.join().unwrap();
        let steps = lock(&metrics).decode_steps();
        // without the deadline only the shutdown flush decodes (~1 step);
        // 150ms of traffic against a 20ms deadline must yield several
        assert!(steps >= 3, "only {steps} decode steps under sustained traffic");
        assert!(*lock(&delivered) >= steps, "every step must deliver");
    }

    #[test]
    fn failed_execute_keeps_serving() {
        let pool = WorkerPool::global();
        let meta = VariantMeta { capacity: 8, m: 8 };
        let (tx, rx) = std::sync::mpsc::channel();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        for id in 0..4u64 {
            tx.send(StreamEvent::Append { session: id, points: vec![1.0; 6] }).unwrap();
        }
        drop(tx);
        let mut calls = 0;
        let delivered = Arc::new(Mutex::new(0usize));
        let sink = Arc::clone(&delivered);
        run_stream_stages(
            rx,
            meta,
            test_cfg(),
            pool,
            Arc::clone(&metrics),
            FaultPolicy::default(),
            move |step| {
                calls += 1;
                if calls == 1 {
                    anyhow::bail!("synthetic device fault");
                }
                Ok(vec![Vec::new(); step.rows])
            },
            move |_, _| *lock(&sink) += 1,
        )
        .unwrap();
        // the transient fault is absorbed by the default retry policy:
        // the step's sessions are still delivered, and the retry counted
        assert_eq!(*lock(&delivered), 4, "retry must recover the step");
        assert!(lock(&metrics).faults().step_retries >= 1);
    }

    /// Requeue-after-fault: with retries disabled, an exhausted decode
    /// step's sessions must not lose their window — the recycled buffer's
    /// fault flag re-enqueues them and a later step serves them.
    #[test]
    fn faulted_step_requeues_windows_for_a_later_step() {
        let pool = WorkerPool::global();
        let meta = VariantMeta { capacity: 8, m: 8 };
        let (tx, rx) = std::sync::mpsc::channel();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let feeder = std::thread::spawn(move || {
            for id in 0..4u64 {
                tx.send(StreamEvent::Append { session: id, points: vec![1.0; 6] }).unwrap();
            }
            // keep the intake open so the prep loop keeps polling and can
            // harvest the faulted buffer before the shutdown flush
            std::thread::sleep(Duration::from_millis(150));
        });
        let faults = FaultPolicy { max_retries: 0, ..FaultPolicy::default() };
        let mut calls = 0;
        let delivered = Arc::new(Mutex::new(Vec::<u64>::new()));
        let sink = Arc::clone(&delivered);
        run_stream_stages(
            rx,
            meta,
            test_cfg(),
            pool,
            Arc::clone(&metrics),
            faults,
            move |step| {
                calls += 1;
                if calls == 1 {
                    anyhow::bail!("synthetic device fault");
                }
                Ok(vec![Vec::new(); step.rows])
            },
            move |id, _| lock(&sink).push(id),
        )
        .unwrap();
        feeder.join().unwrap();
        let got = lock(&delivered);
        for id in 0..4u64 {
            assert!(got.iter().any(|&s| s == id), "session {id} lost its faulted window");
        }
        let mx = lock(&metrics);
        assert!(mx.faults().step_faults >= 1, "the exhausted step must be counted");
        let (_, stats) = mx.stream_snapshot().expect("stream stats recorded");
        assert!(stats.requeued_windows >= 4, "windows requeued: {:?}", stats);
    }
}
