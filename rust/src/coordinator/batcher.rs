//! Dynamic batcher: groups per-variant request queues into execution
//! batches under a max-batch-size / max-wait policy with backpressure.
//!
//! Requests routed to the same artifact variant accumulate until either
//! the artifact's batch capacity is reached or the oldest request has
//! waited `max_wait`; short batches are padded (by repeating the last
//! element) to the artifact's static batch size and the padding is
//! discarded on the way out.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// static batch capacity of the compiled artifact
    pub capacity: usize,
    /// flush a partial batch once its oldest member waited this long
    pub max_wait: Duration,
    /// reject enqueues beyond this depth (backpressure)
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            capacity: 8,
            max_wait: Duration::from_millis(20),
            max_queue: 1024,
        }
    }
}

struct Pending<T> {
    item: T,
    enqueued: Instant,
}

/// One variant's queue.  Generic over the request payload so unit tests
/// don't need real requests.
pub struct DynamicBatcher<T> {
    config: BatcherConfig,
    queue: VecDeque<Pending<T>>,
}

impl<T> DynamicBatcher<T> {
    pub fn new(config: BatcherConfig) -> Self {
        DynamicBatcher { config, queue: VecDeque::new() }
    }

    /// Enqueue a request; `Err` signals backpressure (queue full).
    pub fn push(&mut self, item: T) -> Result<(), T> {
        if self.queue.len() >= self.config.max_queue {
            return Err(item);
        }
        self.queue.push_back(Pending { item, enqueued: Instant::now() });
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Whether a batch should be flushed now.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.config.capacity {
            return true;
        }
        match self.queue.front() {
            Some(p) => now.duration_since(p.enqueued) >= self.config.max_wait,
            None => false,
        }
    }

    /// Enqueue time of the oldest pending request (`None` when empty) —
    /// the key [`drain_ready`] orders flushes by.
    pub fn oldest(&self) -> Option<Instant> {
        self.queue.front().map(|p| p.enqueued)
    }

    /// Time until the oldest request hits max_wait (for the server's poll
    /// timeout); `None` when the queue is empty.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|p| {
            self.config
                .max_wait
                .checked_sub(now.duration_since(p.enqueued))
                .unwrap_or(Duration::ZERO)
        })
    }

    /// Pop up to `capacity` requests as one batch.
    pub fn drain_batch(&mut self) -> Vec<T> {
        let n = self.queue.len().min(self.config.capacity);
        self.queue.drain(..n).map(|p| p.item).collect()
    }
}

/// Flush every ready queue of a multi-variant queue set, **in deadline
/// order**: among the queues that are ready, the one whose oldest pending
/// request enqueued earliest is drained first, then readiness is
/// re-evaluated.
///
/// The serving loop previously iterated the map in key order and drained
/// each queue to exhaustion (`for (name, q) in queues { while q.ready() ..
/// }`), so a hot early-named variant could starve later queues past their
/// `max_wait` deadline indefinitely.  Oldest-first interleaving bounds
/// every variant's flush delay by the work of the batches genuinely ahead
/// of it.
pub fn drain_ready<K: Ord + Clone, T>(
    queues: &mut BTreeMap<K, DynamicBatcher<T>>,
    now: Instant,
) -> Vec<(K, Vec<T>)> {
    let mut flushed = Vec::new();
    loop {
        let next: Option<K> = queues
            .iter()
            .filter(|(_, q)| q.ready(now))
            .min_by_key(|(_, q)| q.oldest().expect("ready queue has a front"))
            .map(|(k, _)| k.clone());
        match next {
            Some(k) => {
                let batch = queues.get_mut(&k).expect("key from iteration").drain_batch();
                flushed.push((k, batch));
            }
            None => return flushed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(capacity: usize, wait_ms: u64, max_queue: usize) -> BatcherConfig {
        BatcherConfig {
            capacity,
            max_wait: Duration::from_millis(wait_ms),
            max_queue,
        }
    }

    #[test]
    fn flushes_on_capacity() {
        let mut b = DynamicBatcher::new(cfg(4, 1000, 100));
        for i in 0..3 {
            b.push(i).unwrap();
        }
        assert!(!b.ready(Instant::now()));
        b.push(3).unwrap();
        assert!(b.ready(Instant::now()));
        assert_eq!(b.drain_batch(), vec![0, 1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = DynamicBatcher::new(cfg(8, 5, 100));
        b.push(1).unwrap();
        assert!(!b.ready(Instant::now()));
        std::thread::sleep(Duration::from_millis(7));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.drain_batch(), vec![1]);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut b = DynamicBatcher::new(cfg(2, 10, 3));
        for i in 0..3 {
            b.push(i).unwrap();
        }
        assert_eq!(b.push(99), Err(99));
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn drain_respects_capacity() {
        let mut b = DynamicBatcher::new(cfg(2, 10, 10));
        for i in 0..5 {
            b.push(i).unwrap();
        }
        assert_eq!(b.drain_batch(), vec![0, 1]);
        assert_eq!(b.drain_batch(), vec![2, 3]);
        assert_eq!(b.drain_batch(), vec![4]);
    }

    #[test]
    fn drain_ready_prefers_oldest_pending() {
        // "b" receives its (single) request first, then "a" fills to
        // capacity; with max_wait 0 both are ready, and the old fixed-order
        // loop would flush "a" first.  Deadline order must flush "b" first.
        let mut queues: BTreeMap<&str, DynamicBatcher<u32>> = BTreeMap::new();
        queues.insert("a", DynamicBatcher::new(cfg(2, 0, 100)));
        queues.insert("b", DynamicBatcher::new(cfg(2, 0, 100)));
        queues.get_mut("b").unwrap().push(99).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        queues.get_mut("a").unwrap().push(1).unwrap();
        queues.get_mut("a").unwrap().push(2).unwrap();
        let flushed = drain_ready(&mut queues, Instant::now());
        assert_eq!(flushed.len(), 2);
        assert_eq!(flushed[0], ("b", vec![99]));
        assert_eq!(flushed[1], ("a", vec![1, 2]));
        assert!(queues.values().all(|q| q.is_empty()));
    }

    #[test]
    fn drain_ready_interleaves_hot_queue_with_starved_one() {
        // Regression for the flush-starvation bug: "a" (early in key
        // order) holds many full batches; "z" has one older-than-deadline
        // request.  "z" must not wait for all of "a"'s backlog.
        let mut queues: BTreeMap<&str, DynamicBatcher<u32>> = BTreeMap::new();
        queues.insert("a", DynamicBatcher::new(cfg(2, 0, 100)));
        queues.insert("z", DynamicBatcher::new(cfg(8, 0, 100)));
        queues.get_mut("z").unwrap().push(7).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        for i in 0..6 {
            queues.get_mut("a").unwrap().push(i).unwrap();
        }
        let flushed = drain_ready(&mut queues, Instant::now());
        assert_eq!(flushed[0].0, "z", "starved queue must flush first");
        assert_eq!(flushed.len(), 4); // z once + a three times (capacity 2)
        assert!(flushed[1..].iter().all(|(k, _)| *k == "a"));
    }

    #[test]
    fn drain_ready_leaves_unready_queues_alone() {
        let mut queues: BTreeMap<&str, DynamicBatcher<u32>> = BTreeMap::new();
        queues.insert("a", DynamicBatcher::new(cfg(4, 10_000, 100)));
        queues.get_mut("a").unwrap().push(1).unwrap();
        assert!(drain_ready(&mut queues, Instant::now()).is_empty());
        assert_eq!(queues["a"].len(), 1);
    }

    #[test]
    fn deadline_decreases_over_time() {
        let mut b = DynamicBatcher::new(cfg(8, 50, 10));
        b.push(1).unwrap();
        let now = Instant::now();
        let d1 = b.next_deadline(now).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let d2 = b.next_deadline(Instant::now()).unwrap();
        assert!(d2 <= d1);
        assert!(b.next_deadline(now) <= Some(Duration::from_millis(50)));
    }
}
