//! Dynamic batcher: groups per-variant request queues into execution
//! batches under a max-batch-size / max-wait policy with backpressure.
//!
//! Requests routed to the same artifact variant accumulate until either
//! the artifact's batch capacity is reached or the oldest request has
//! waited `max_wait`; short batches are padded (by repeating the last
//! element) to the artifact's static batch size and the padding is
//! discarded on the way out.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// static batch capacity of the compiled artifact
    pub capacity: usize,
    /// flush a partial batch once its oldest member waited this long
    pub max_wait: Duration,
    /// reject enqueues beyond this depth (backpressure)
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            capacity: 8,
            max_wait: Duration::from_millis(20),
            max_queue: 1024,
        }
    }
}

struct Pending<T> {
    item: T,
    enqueued: Instant,
}

/// One variant's queue.  Generic over the request payload so unit tests
/// don't need real requests.
pub struct DynamicBatcher<T> {
    config: BatcherConfig,
    queue: VecDeque<Pending<T>>,
}

impl<T> DynamicBatcher<T> {
    pub fn new(config: BatcherConfig) -> Self {
        DynamicBatcher { config, queue: VecDeque::new() }
    }

    /// Enqueue a request; `Err` signals backpressure (queue full).
    pub fn push(&mut self, item: T) -> Result<(), T> {
        if self.queue.len() >= self.config.max_queue {
            return Err(item);
        }
        self.queue.push_back(Pending { item, enqueued: Instant::now() });
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Whether a batch should be flushed now.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.config.capacity {
            return true;
        }
        match self.queue.front() {
            Some(p) => now.duration_since(p.enqueued) >= self.config.max_wait,
            None => false,
        }
    }

    /// Time until the oldest request hits max_wait (for the server's poll
    /// timeout); `None` when the queue is empty.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|p| {
            self.config
                .max_wait
                .checked_sub(now.duration_since(p.enqueued))
                .unwrap_or(Duration::ZERO)
        })
    }

    /// Pop up to `capacity` requests as one batch.
    pub fn drain_batch(&mut self) -> Vec<T> {
        let n = self.queue.len().min(self.config.capacity);
        self.queue.drain(..n).map(|p| p.item).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(capacity: usize, wait_ms: u64, max_queue: usize) -> BatcherConfig {
        BatcherConfig {
            capacity,
            max_wait: Duration::from_millis(wait_ms),
            max_queue,
        }
    }

    #[test]
    fn flushes_on_capacity() {
        let mut b = DynamicBatcher::new(cfg(4, 1000, 100));
        for i in 0..3 {
            b.push(i).unwrap();
        }
        assert!(!b.ready(Instant::now()));
        b.push(3).unwrap();
        assert!(b.ready(Instant::now()));
        assert_eq!(b.drain_batch(), vec![0, 1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = DynamicBatcher::new(cfg(8, 5, 100));
        b.push(1).unwrap();
        assert!(!b.ready(Instant::now()));
        std::thread::sleep(Duration::from_millis(7));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.drain_batch(), vec![1]);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut b = DynamicBatcher::new(cfg(2, 10, 3));
        for i in 0..3 {
            b.push(i).unwrap();
        }
        assert_eq!(b.push(99), Err(99));
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn drain_respects_capacity() {
        let mut b = DynamicBatcher::new(cfg(2, 10, 10));
        for i in 0..5 {
            b.push(i).unwrap();
        }
        assert_eq!(b.drain_batch(), vec![0, 1]);
        assert_eq!(b.drain_batch(), vec![2, 3]);
        assert_eq!(b.drain_batch(), vec![4]);
    }

    #[test]
    fn deadline_decreases_over_time() {
        let mut b = DynamicBatcher::new(cfg(8, 50, 10));
        b.push(1).unwrap();
        let now = Instant::now();
        let d1 = b.next_deadline(now).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let d2 = b.next_deadline(Instant::now()).unwrap();
        assert!(d2 <= d1);
        assert!(b.next_deadline(now) <= Some(Duration::from_millis(50)));
    }
}
