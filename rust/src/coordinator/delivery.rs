//! Delivery accounting for stream forecasts (DESIGN.md §10).
//!
//! The dual serving loop used to push rolling forecasts into a
//! fire-and-forget `(session, forecast)` channel: a slow collector made
//! it grow without bound, a dead one lost every forecast silently, and a
//! dropped message was indistinguishable from one never produced.  The
//! [`DeliveryMonitor`] replaces it with a per-session **bounded outbox**
//! with at-least-once semantics:
//!
//! * `offer` enqueues a forecast under a per-session monotonic sequence
//!   number; when the outbox is full the *oldest* unacked entry is
//!   dropped and counted (`dropped_overflow`) — memory stays within
//!   `cap` per session, asserted by the fault suite.
//! * `collect` hands back every unacked forecast in sequence order;
//!   forecasts seen by a previous `collect` are counted as redelivered.
//!   Order within a session is the enqueue order, always.
//! * `ack(session, upto)` retires delivered forecasts.
//! * `expire` drops unacked forecasts older than the TTL
//!   (`expired_undelivered`) and forgets sessions idle past the TTL.

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

/// Delivery counters, merged into the serving [`Metrics`](super::Metrics)
/// report.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeliveryStats {
    pub enqueued: u64,
    pub acked: u64,
    /// forecasts handed out by `collect` more than once
    pub redelivered: u64,
    /// unacked forecasts dropped by TTL expiry
    pub expired_undelivered: u64,
    /// unacked forecasts dropped because the outbox was full
    pub dropped_overflow: u64,
    /// forecasts still queued at snapshot time — closes the ledger:
    /// `enqueued == acked + expired_undelivered + dropped_overflow +
    /// pending` holds for every snapshot, and (being an identity, not a
    /// rate) still holds after summing snapshots across shards
    pub pending: u64,
}

#[derive(Debug)]
struct Entry {
    seq: u64,
    forecast: Vec<f32>,
    enqueued: Instant,
    /// times `collect` has handed this entry out
    deliveries: u32,
}

#[derive(Debug, Default)]
struct Outbox {
    queue: VecDeque<Entry>,
    next_seq: u64,
    last_touch: Option<Instant>,
}

/// Per-session bounded outboxes for stream forecasts; see module docs.
/// Not internally synchronized — the server shares it behind a mutex.
#[derive(Debug)]
pub struct DeliveryMonitor {
    cap: usize,
    ttl: Duration,
    outboxes: HashMap<u64, Outbox>,
    stats: DeliveryStats,
}

impl DeliveryMonitor {
    pub fn new(cap: usize, ttl: Duration) -> Self {
        Self { cap: cap.max(1), ttl, outboxes: HashMap::new(), stats: DeliveryStats::default() }
    }

    /// Enqueue a forecast for `session`, evicting the oldest unacked
    /// entry if the outbox is at capacity.  Returns the forecast's
    /// sequence number.
    pub fn offer(&mut self, session: u64, forecast: Vec<f32>, now: Instant) -> u64 {
        let outbox = self.outboxes.entry(session).or_default();
        if outbox.queue.len() >= self.cap {
            outbox.queue.pop_front();
            self.stats.dropped_overflow += 1;
        }
        let seq = outbox.next_seq;
        outbox.next_seq += 1;
        outbox.queue.push_back(Entry { seq, forecast, enqueued: now, deliveries: 0 });
        outbox.last_touch = Some(now);
        self.stats.enqueued += 1;
        seq
    }

    /// Every unacked forecast for `session`, oldest first, as
    /// `(seq, forecast)`.  Entries stay queued until [`ack`]ed; a repeat
    /// collect redelivers them (and counts the redelivery).
    ///
    /// [`ack`]: DeliveryMonitor::ack
    pub fn collect(&mut self, session: u64) -> Vec<(u64, Vec<f32>)> {
        let Some(outbox) = self.outboxes.get_mut(&session) else {
            return Vec::new();
        };
        let mut out = Vec::with_capacity(outbox.queue.len());
        for entry in outbox.queue.iter_mut() {
            if entry.deliveries > 0 {
                self.stats.redelivered += 1;
            }
            entry.deliveries += 1;
            out.push((entry.seq, entry.forecast.clone()));
        }
        out
    }

    /// Retire every entry of `session` with `seq <= upto`.  Returns how
    /// many were acked (idempotent: re-acking is a no-op).
    pub fn ack(&mut self, session: u64, upto: u64, now: Instant) -> usize {
        let Some(outbox) = self.outboxes.get_mut(&session) else {
            return 0;
        };
        let mut acked = 0;
        while outbox.queue.front().is_some_and(|e| e.seq <= upto) {
            outbox.queue.pop_front();
            acked += 1;
        }
        outbox.last_touch = Some(now);
        self.stats.acked += acked as u64;
        acked
    }

    /// Drop unacked forecasts older than the TTL (counted as
    /// `expired_undelivered`) and forget sessions whose outbox is empty
    /// and idle past the TTL.  Returns how many forecasts expired.
    pub fn expire(&mut self, now: Instant) -> usize {
        let ttl = self.ttl;
        let mut expired = 0usize;
        self.outboxes.retain(|_, outbox| {
            while outbox
                .queue
                .front()
                .is_some_and(|e| now.duration_since(e.enqueued) >= ttl)
            {
                outbox.queue.pop_front();
                expired += 1;
            }
            !outbox.queue.is_empty()
                || outbox
                    .last_touch
                    .map_or(true, |t| now.duration_since(t) < ttl)
        });
        self.stats.expired_undelivered += expired as u64;
        expired
    }

    /// Unacked forecasts queued for `session`.
    pub fn pending(&self, session: u64) -> usize {
        self.outboxes.get(&session).map_or(0, |o| o.queue.len())
    }

    /// Unacked forecasts across all sessions.
    pub fn total_pending(&self) -> usize {
        self.outboxes.values().map(|o| o.queue.len()).sum()
    }

    /// Largest single-session outbox depth — by construction `<= cap`,
    /// asserted (not just logged) by the fault-injection suite.
    pub fn max_outbox_depth(&self) -> usize {
        self.outboxes.values().map(|o| o.queue.len()).max().unwrap_or(0)
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Counter snapshot; `pending` is computed at snapshot time so the
    /// ledger identity (see [`DeliveryStats::pending`]) always balances.
    pub fn stats(&self) -> DeliveryStats {
        DeliveryStats { pending: self.total_pending() as u64, ..self.stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn offer_collect_ack_roundtrip() {
        let mut m = DeliveryMonitor::new(8, Duration::from_secs(60));
        let now = t0();
        assert_eq!(m.offer(1, vec![1.0], now), 0);
        assert_eq!(m.offer(1, vec![2.0], now), 1);
        assert_eq!(m.offer(2, vec![9.0], now), 0, "sequences are per-session");
        let got = m.collect(1);
        assert_eq!(got, vec![(0, vec![1.0]), (1, vec![2.0])]);
        assert_eq!(m.ack(1, 1, now), 2);
        assert!(m.collect(1).is_empty());
        assert_eq!(m.pending(2), 1);
        let s = m.stats();
        assert_eq!((s.enqueued, s.acked, s.redelivered), (3, 2, 0));
        assert_eq!(s.pending, 1, "session 2's forecast is still queued");
        assert_eq!(
            s.enqueued,
            s.acked + s.expired_undelivered + s.dropped_overflow + s.pending,
            "ledger identity"
        );
    }

    #[test]
    fn uncollected_forecasts_are_redelivered_in_order() {
        let mut m = DeliveryMonitor::new(8, Duration::from_secs(60));
        let now = t0();
        for i in 0..3 {
            m.offer(5, vec![i as f32], now);
        }
        let first = m.collect(5);
        // ack only the first entry; the rest must come back, in order
        m.ack(5, 0, now);
        m.offer(5, vec![3.0], now);
        let second = m.collect(5);
        assert_eq!(first.len(), 3);
        assert_eq!(
            second.iter().map(|(s, _)| *s).collect::<Vec<_>>(),
            vec![1, 2, 3],
            "unacked survive, order preserved, new entry appended"
        );
        assert_eq!(m.stats().redelivered, 2, "entries 1 and 2 were redelivered");
    }

    #[test]
    fn overflow_drops_oldest_and_counts() {
        let mut m = DeliveryMonitor::new(3, Duration::from_secs(60));
        let now = t0();
        for i in 0..10u64 {
            m.offer(1, vec![i as f32], now);
            assert!(m.pending(1) <= 3, "outbox beyond its bound");
        }
        assert_eq!(m.stats().dropped_overflow, 7);
        // the survivors are the newest three, still in order
        let seqs: Vec<u64> = m.collect(1).iter().map(|(s, _)| *s).collect();
        assert_eq!(seqs, vec![7, 8, 9]);
        assert_eq!(m.max_outbox_depth(), 3);
    }

    #[test]
    fn ttl_expires_unacked_and_forgets_idle_sessions() {
        let mut m = DeliveryMonitor::new(8, Duration::from_millis(10));
        let now = t0();
        m.offer(1, vec![1.0], now);
        m.offer(1, vec![2.0], now + Duration::from_millis(8));
        assert_eq!(m.expire(now + Duration::from_millis(5)), 0, "nothing old enough");
        assert_eq!(m.expire(now + Duration::from_millis(12)), 1, "first entry expired");
        assert_eq!(m.pending(1), 1);
        assert_eq!(m.expire(now + Duration::from_millis(30)), 1, "second follows");
        assert_eq!(m.stats().expired_undelivered, 2);
        // idle empty outbox is eventually forgotten entirely
        assert_eq!(m.expire(now + Duration::from_secs(1)), 0);
        assert_eq!(m.total_pending(), 0);
        assert!(m.outboxes.is_empty(), "idle session table entry must be reclaimed");
    }

    #[test]
    fn ack_is_idempotent_and_ignores_unknown_sessions() {
        let mut m = DeliveryMonitor::new(4, Duration::from_secs(60));
        let now = t0();
        m.offer(1, vec![1.0], now);
        assert_eq!(m.ack(1, 0, now), 1);
        assert_eq!(m.ack(1, 0, now), 0);
        assert_eq!(m.ack(99, 5, now), 0);
    }
}
