//! Layer-3 coordinator: the serving system around the compiled artifacts.
//!
//! The paper accelerates *inference of already-trained models*; the natural
//! systems shape is a forecast-serving coordinator (DESIGN.md §2):
//!
//! * `policy`  — merge-policy planner: picks the merge-rate variant per
//!   request from cheap input statistics (spectral entropy / adjacent
//!   token similarity), i.e. the serving-level realisation of §5.5
//!   dynamic merging.
//! * `batcher` — dynamic batcher: groups requests per variant under a
//!   max-batch / max-wait policy and pads to the artifact batch size.
//! * `server`  — executor thread owning the PJRT engine (PJRT handles are
//!   not `Send`, so all device work lives on one thread — the same
//!   topology as a single-accelerator serving process) plus the client
//!   handle and request plumbing.
//! * `metrics` — latency/throughput accounting for the benchmark harness.

pub mod batcher;
pub mod metrics;
pub mod policy;
#[cfg(feature = "pjrt")]
pub mod server;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use metrics::Metrics;
pub use policy::{EntropyCache, MergePolicy, PolicyDecision};
#[cfg(feature = "pjrt")]
pub use server::{Client, ServerHandle};

/// Serving configuration (lives here rather than in `server` so the config
/// system parses/validates it in builds without the `pjrt` feature).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifact_dir: std::path::PathBuf,
    pub policy: MergePolicy,
    pub max_wait: std::time::Duration,
    pub max_queue: usize,
}

/// A forecast request: univariate context, horizon fixed by the artifact.
#[derive(Clone, Debug)]
pub struct ForecastRequest {
    pub id: u64,
    pub context: Vec<f32>,
}

/// A served forecast.
#[derive(Clone, Debug)]
pub struct ForecastResponse {
    pub id: u64,
    pub forecast: Vec<f32>,
    /// artifact variant that served this request
    pub variant: String,
    /// end-to-end latency (seconds) from enqueue to response
    pub latency: f64,
    /// batch size this request was served in
    pub batch_size: usize,
}
