//! Layer-3 coordinator: the staged serving system around the compiled
//! artifacts.
//!
//! The paper accelerates *inference of already-trained models*; the
//! systems shape is a forecast-serving coordinator whose host-side work is
//! overlapped with device execution (the "merge-while-execute" pipeline):
//!
//! * `policy`   — merge-policy planner: picks the merge-rate variant per
//!   request from cheap input statistics (spectral entropy via the
//!   memoized `EntropyCache`), i.e. the serving-level realisation of §5.5
//!   dynamic merging.
//! * `batcher`  — dynamic batcher: groups requests per (variant, context
//!   length) under a max-batch / max-wait policy (length-uniform batches
//!   share one premerge schedule).  `drain_ready` flushes a multi-queue
//!   set in **deadline order** (oldest pending request first), so a hot
//!   queue can no longer starve the others past their `max_wait`.
//! * `pipeline` — the staged core (PJRT-free, so benches and tests can
//!   drive it with a synthetic device): a prep stage that pads input
//!   slabs and **premerges over-length contexts on the shared
//!   `WorkerPool`**, double-buffered against the execute stage so batch
//!   N+1's host work overlaps batch N's `model.execute`.
//! * `server`   — the three serving threads (`pjrt` feature): an intake
//!   thread (routing + batching, owns the client channel), the prep
//!   thread, and the execute thread owning the PJRT engine (PJRT handles
//!   are not `Send`, so all device work stays on one thread) — wired
//!   together by `pipeline::run_stages`.
//! * `stream`   — the streaming decode scheduler (DESIGN.md §9): drives
//!   the session-managed incremental-merge subsystem
//!   (`crate::streaming`), continuously batching decode-ready sessions
//!   into a staged prep/execute pipeline of the same shape as
//!   `pipeline::run_stages` — PJRT-free and generic over the device
//!   closure, like the batch core.
//! * `serve_loop` — the dual serving loop: when a `"streaming"` block is
//!   configured, the batch and stream prep stages feed one tagged
//!   `ReadyWork` channel and a single device thread executes both —
//!   the topology `tomers serve` runs (PJRT-free, synthetic-device
//!   testable).
//! * `metrics`  — latency/throughput accounting shared across the stages,
//!   including session-level streaming counters.
//! * `faults`   — fault tolerance (DESIGN.md §10): retry with backoff +
//!   deadlines around device execution, per-variant quarantine behind
//!   graceful degradation, and the seeded fault-injection harness.
//! * `delivery` — per-session bounded outboxes with ack/redelivery/TTL
//!   accounting for stream forecasts, replacing the fire-and-forget
//!   forecast channel.
//!
//! The network front (`crate::net`, DESIGN.md §12) stacks on top of this
//! layer: each shard of `tomers serve-net` runs its own
//! `serve_loop::run_serve_stages` instance (own device thread, session
//! table, `DeliveryMonitor`, bounded intake), and per-shard [`Metrics`]
//! roll up through [`metrics::merged_report`].

pub mod batcher;
pub mod delivery;
pub mod faults;
pub mod metrics;
pub mod pipeline;
pub mod policy;
pub mod serve_loop;
#[cfg(feature = "pjrt")]
pub mod server;
pub mod stream;

pub use batcher::{drain_ready, BatcherConfig, DynamicBatcher};
pub use delivery::{DeliveryMonitor, DeliveryStats};
pub use faults::{call_with_retry, FaultContext, FaultPlan, FaultPolicy, FaultTracker};
pub use metrics::{
    merged_json, merged_report, sum_delivery, CompressionStats, FaultCounters, Metrics,
    RouteStats,
};
pub use pipeline::{default_host_merge, HostPrep, PrepJob, ReadyBatch, VariantMeta};
pub use policy::{
    EntropyCache, MergePolicy, PolicyDecision, SpecResolution, SpecSource, Variant,
};
pub use serve_loop::{resolve_stream_artifact, run_serve_stages, ReadyWork, StreamArtifact};
#[cfg(feature = "pjrt")]
pub use server::{Client, ServerHandle, StreamClient};
pub use stream::{run_stream_stages, DecodeStep, StreamEvent, StreamScheduler};

use crate::merging::MergeSpec;
use crate::streaming::StreamingConfig;

/// Serving configuration (lives here rather than in `server` so the config
/// system parses/validates it in builds without the `pjrt` feature).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifact_dir: std::path::PathBuf,
    pub policy: MergePolicy,
    pub max_wait: std::time::Duration,
    pub max_queue: usize,
    /// worker count for the process-wide `WorkerPool` (0 = machine
    /// default); applied on first use of the pool, so set it before
    /// anything else touches `WorkerPool::global`
    pub merge_workers: usize,
    /// host-side premerge of over-length contexts in the prep stage
    /// ([`MergeSpec::off`] rejects them instead; see
    /// [`pipeline::default_host_merge`])
    pub merge: MergeSpec,
    /// streaming decode subsystem (session-managed continuous batching,
    /// DESIGN.md §9); `None` = batch-only serving.  Under `tomers serve`
    /// the block selects the dual serving loop
    /// ([`serve_loop::run_serve_stages`]): stream decode steps share the
    /// device thread, `WorkerPool` and metrics with the batch pipeline.
    /// `tomers stream` and [`stream::run_stream_stages`] drive the same
    /// stages offline.
    pub streaming: Option<StreamingConfig>,
    /// Prefer each loaded artifact's `Manifest.merge_spec` over the
    /// config's variant declaration (default `true`; the
    /// `"spec_source": "config"` escape hatch sets `false`) — see
    /// [`MergePolicy::prefer_manifest_specs`].
    pub prefer_manifest_spec: bool,
    /// fault handling: device-call retry/backoff, request and decode-step
    /// deadlines, quarantine budgets and the delivery-monitor bounds
    /// (the `"faults"` config block; defaults keep the happy path
    /// unchanged)
    pub faults: FaultPolicy,
}

/// A forecast request: univariate context, horizon fixed by the artifact.
#[derive(Clone, Debug)]
pub struct ForecastRequest {
    pub id: u64,
    pub context: Vec<f32>,
}

/// Terminal outcome of a forecast request.  Every submitted request gets
/// exactly one response with one of these — a device fault or a missed
/// deadline produces a terminal error response, never a silently dropped
/// channel (the pre-fault-tolerance behaviour).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ForecastOutcome {
    /// `forecast` carries the model output
    Delivered,
    /// the request aged past `faults.request_deadline` (or its batch's
    /// retry window was cut short by it); `forecast` is empty
    DeadlineExceeded,
    /// retries exhausted or the batch was unservable; carries the reason
    Failed(String),
}

impl ForecastOutcome {
    pub fn is_delivered(&self) -> bool {
        matches!(self, ForecastOutcome::Delivered)
    }
}

/// A served forecast.
#[derive(Clone, Debug)]
pub struct ForecastResponse {
    pub id: u64,
    pub forecast: Vec<f32>,
    /// artifact variant that served this request
    pub variant: String,
    /// end-to-end latency (seconds) from enqueue to response
    pub latency: f64,
    /// batch size this request was served in
    pub batch_size: usize,
    /// terminal outcome; `forecast` is only meaningful when `Delivered`
    pub outcome: ForecastOutcome,
}
