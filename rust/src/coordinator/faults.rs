//! Fault tolerance for the serving stages (DESIGN.md §10).
//!
//! Three pieces, all PJRT-free so the synthetic-device tests and
//! `tomers serve-sim` exercise exactly what `tomers serve` runs:
//!
//! * [`FaultPolicy`] — the `"faults"` config block: bounded retry with
//!   exponential backoff around every device-execute call, a per-request
//!   deadline (batch side) and a per-decode-step deadline (stream side),
//!   the per-session fault budget that quarantines repeat offenders, the
//!   per-variant fault budget that triggers graceful degradation, and the
//!   delivery-monitor bounds (outbox capacity + forecast TTL).
//! * [`call_with_retry`] — the one retry loop both pipelines share.  It
//!   converts device panics into errors (via `catch_unwind`), backs off
//!   exponentially between attempts, and gives up early when the next
//!   attempt could not finish before the deadline — so a request past its
//!   deadline gets a terminal timeout instead of burning retries.
//! * [`FaultPlan`] — the deterministic fault-injection harness: a seeded
//!   schedule of error / latency-spike / panic injections that wraps any
//!   device closure.  `tests/serve_faults.rs` and `tomers serve-sim`
//!   drive the real serving loops through it.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::util::{lock_ignore_poison as lock, panic_message, Rng};

/// Fault-handling policy for the serving stages — the `"faults"` config
/// block (see `config::ServeFileConfig`), with defaults tuned so the
/// happy path is unchanged: no deadlines, two retries, millisecond
/// backoff.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPolicy {
    /// retries after the first attempt (0 = fail on the first error)
    pub max_retries: usize,
    /// backoff before retry i is `backoff_base * 2^i`, capped at
    /// `backoff_max`
    pub backoff_base: Duration,
    pub backoff_max: Duration,
    /// batch side: a request older than this when its batch reaches the
    /// device gets a terminal `DeadlineExceeded` response and is dropped
    /// from the batch (`None` = no deadline)
    pub request_deadline: Option<Duration>,
    /// stream side: retry budget for one decode step is bounded by this
    /// wall-clock window (`None` = retries alone bound it)
    pub step_deadline: Option<Duration>,
    /// consecutive faulted decode steps a stream session survives before
    /// the `SessionManager` quarantines (evicts) it
    pub session_fault_budget: u32,
    /// consecutive device faults on one variant before routing downgrades
    /// it to the next cheaper variant (0 = degradation disabled)
    pub variant_fault_budget: u32,
    /// per-session delivery-monitor outbox capacity (oldest unacked
    /// forecast is dropped — and counted — when full)
    pub outbox_cap: usize,
    /// unacked forecasts older than this expire (`expired_undelivered`)
    pub forecast_ttl: Duration,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        Self {
            max_retries: 2,
            backoff_base: Duration::from_millis(2),
            backoff_max: Duration::from_millis(250),
            request_deadline: None,
            step_deadline: None,
            session_fault_budget: 3,
            variant_fault_budget: 5,
            outbox_cap: 16,
            forecast_ttl: Duration::from_secs(60),
        }
    }
}

impl FaultPolicy {
    /// Field-naming validation, mirroring `StreamingConfig::validate`.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.backoff_base > Duration::ZERO, "faults.backoff_base_ms must be > 0");
        ensure!(
            self.backoff_max >= self.backoff_base,
            "faults.backoff_max_ms must be >= backoff_base_ms"
        );
        if let Some(d) = self.request_deadline {
            ensure!(d > Duration::ZERO, "faults.request_deadline_ms must be > 0");
        }
        if let Some(d) = self.step_deadline {
            ensure!(d > Duration::ZERO, "faults.step_deadline_ms must be > 0");
        }
        ensure!(self.session_fault_budget >= 1, "faults.session_fault_budget must be >= 1");
        ensure!(self.outbox_cap >= 1, "faults.outbox_cap must be >= 1");
        ensure!(self.forecast_ttl > Duration::ZERO, "faults.forecast_ttl_ms must be > 0");
        Ok(())
    }

    /// Backoff before retry `attempt` (0-based): `base * 2^attempt`,
    /// saturating at `backoff_max`.
    pub fn backoff(&self, attempt: usize) -> Duration {
        let factor = 1u32.checked_shl(attempt.min(31) as u32).unwrap_or(u32::MAX);
        self.backoff_base.checked_mul(factor).unwrap_or(self.backoff_max).min(self.backoff_max)
    }
}

/// What [`call_with_retry`] concluded.
#[derive(Debug)]
pub struct RetryOutcome<R> {
    /// the last attempt's result (an error carries the last failure; see
    /// `timed_out` to distinguish deadline abort from retry exhaustion)
    pub result: Result<R>,
    /// attempts actually made (>= 1)
    pub attempts: usize,
    /// true when the deadline — not the retry budget — stopped us
    pub timed_out: bool,
}

/// Run `call` with the policy's bounded retry + exponential backoff,
/// converting panics into errors so a panicking device closure is a
/// fault like any other, not a dead serving thread.  `deadline` (if any)
/// bounds the whole retry budget: once reached — or once the next
/// backoff would overshoot it — the loop gives up with `timed_out`.
pub fn call_with_retry<R>(
    policy: &FaultPolicy,
    deadline: Option<Instant>,
    what: &str,
    mut call: impl FnMut() -> Result<R>,
) -> RetryOutcome<R> {
    let mut attempts = 0usize;
    loop {
        if let Some(d) = deadline {
            if Instant::now() >= d {
                return RetryOutcome {
                    result: Err(anyhow!("{what}: deadline exceeded after {attempts} attempts")),
                    attempts,
                    timed_out: true,
                };
            }
        }
        attempts += 1;
        let attempt = catch_unwind(AssertUnwindSafe(&mut call))
            .unwrap_or_else(|p| Err(anyhow!("{what} panicked: {}", panic_message(&*p))));
        match attempt {
            Ok(r) => return RetryOutcome { result: Ok(r), attempts, timed_out: false },
            Err(e) => {
                if attempts > policy.max_retries {
                    return RetryOutcome {
                        result: Err(e.context(format!(
                            "{what}: retries exhausted ({attempts} attempts)"
                        ))),
                        attempts,
                        timed_out: false,
                    };
                }
                let backoff = policy.backoff(attempts - 1);
                if let Some(d) = deadline {
                    if Instant::now() + backoff >= d {
                        return RetryOutcome {
                            result: Err(e.context(format!(
                                "{what}: deadline exceeded after {attempts} attempts"
                            ))),
                            attempts,
                            timed_out: true,
                        };
                    }
                }
                std::thread::sleep(backoff);
            }
        }
    }
}

/// Per-variant consecutive-fault tracker behind graceful degradation:
/// once a variant faults `budget` times in a row (retry-exhausted
/// batches, not individual attempts), it is quarantined and routing
/// downgrades to the next cheaper variant.  A later success on the
/// variant (e.g. via an explicitly-routed stream artifact) clears it.
#[derive(Debug, Default)]
pub struct FaultTracker {
    consecutive: BTreeMap<String, u32>,
    budget: u32,
}

impl FaultTracker {
    /// `budget = 0` disables quarantine (the tracker still counts).
    pub fn new(budget: u32) -> Self {
        Self { consecutive: BTreeMap::new(), budget }
    }

    pub fn record_success(&mut self, variant: &str) {
        self.consecutive.remove(variant);
    }

    /// Count one exhausted fault; returns true when this crossing of the
    /// budget newly quarantined the variant.
    pub fn record_fault(&mut self, variant: &str) -> bool {
        let n = self.consecutive.entry(variant.to_string()).or_insert(0);
        *n += 1;
        self.budget > 0 && *n == self.budget
    }

    pub fn is_quarantined(&self, variant: &str) -> bool {
        self.budget > 0
            && self.consecutive.get(variant).is_some_and(|&n| n >= self.budget)
    }

    /// Downgrade target: walk from `variant` toward the cheapest variant
    /// (`ordered[0]`, the no-merge path is by convention last-resort in
    /// the *other* direction cost-wise — cheaper here means *less merged*,
    /// i.e. the more conservative artifact) and return the first
    /// non-quarantined name.  `None` when everything is quarantined.
    pub fn fallback<'a>(&self, ordered: &'a [String], variant: &str) -> Option<&'a str> {
        let pos = ordered.iter().position(|v| v == variant)?;
        ordered[..pos]
            .iter()
            .rev()
            .map(String::as_str)
            .find(|v| !self.is_quarantined(v))
    }
}

/// The fault-handling context threaded through the batch pipeline: the
/// policy plus the shared variant tracker (shared with the intake thread,
/// which consults it for routing downgrades).
#[derive(Clone, Debug)]
pub struct FaultContext {
    pub policy: FaultPolicy,
    pub tracker: Arc<Mutex<FaultTracker>>,
}

impl FaultContext {
    pub fn new(policy: FaultPolicy) -> Self {
        let tracker = Arc::new(Mutex::new(FaultTracker::new(policy.variant_fault_budget)));
        Self { policy, tracker }
    }
}

impl Default for FaultContext {
    fn default() -> Self {
        Self::new(FaultPolicy::default())
    }
}

/// One scheduled injection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Injection {
    Error,
    Delay,
    Panic,
}

/// Deterministic fault-injection schedule: wraps a device closure and
/// injects errors (dominant), latency spikes, and panics at `fault_rate`,
/// reproducibly per seed.  Counters let harnesses assert accounting.
#[derive(Debug)]
pub struct FaultPlan {
    rng: Rng,
    fault_rate: f64,
    delay: Duration,
    calls: u64,
    pub injected_errors: u64,
    pub injected_delays: u64,
    pub injected_panics: u64,
}

impl FaultPlan {
    pub fn new(seed: u64, fault_rate: f64) -> Self {
        Self {
            rng: Rng::new(seed),
            fault_rate: fault_rate.clamp(0.0, 1.0),
            delay: Duration::from_millis(5),
            calls: 0,
            injected_errors: 0,
            injected_delays: 0,
            injected_panics: 0,
        }
    }

    pub fn injected(&self) -> u64 {
        self.injected_errors + self.injected_delays + self.injected_panics
    }

    /// Device calls that passed through the plan (clean or injected).
    pub fn calls(&self) -> u64 {
        self.calls
    }

    /// Decide this call's fate.  Panics and latency spikes each take a
    /// tenth of the fault budget; plain errors the rest — errors dominate
    /// so retry (not the panic path) is the main exercised machinery.
    fn next(&mut self) -> Option<Injection> {
        self.calls += 1;
        let u = self.rng.uniform();
        if u >= self.fault_rate {
            return None;
        }
        let kind = u / self.fault_rate; // uniform in [0, 1) given a fault
        Some(if kind < 0.1 {
            Injection::Panic
        } else if kind < 0.2 {
            Injection::Delay
        } else {
            Injection::Error
        })
    }

    /// Injection gate for device closures that take borrowed work items
    /// (`FnMut(&mut ReadyBatch)` and friends), where the generic
    /// [`Self::wrap`] cannot satisfy the higher-ranked closure bound:
    /// call it first inside the closure.  Decides this call's fate — an
    /// injected error returns `Err` without executing, a latency spike
    /// sleeps then returns `Ok` (the real work still runs), a panic
    /// panics (exercising the `catch_unwind` path in
    /// [`call_with_retry`]), and a clean call returns `Ok` immediately.
    pub fn gate(plan: &Arc<Mutex<FaultPlan>>) -> Result<()> {
        let (injection, n, delay) = {
            let mut p = lock(plan);
            let injection = p.next();
            match injection {
                Some(Injection::Error) => p.injected_errors += 1,
                Some(Injection::Delay) => p.injected_delays += 1,
                Some(Injection::Panic) => p.injected_panics += 1,
                None => {}
            }
            (injection, p.calls, p.delay)
        };
        match injection {
            None => Ok(()),
            Some(Injection::Delay) => {
                std::thread::sleep(delay);
                Ok(())
            }
            Some(Injection::Error) => Err(anyhow!("injected fault #{n}")),
            Some(Injection::Panic) => panic!("injected panic #{n}"),
        }
    }

    /// Wrap a device closure over an owned argument: shared handle +
    /// inner call → faulty call, via [`Self::gate`].
    pub fn wrap<A, R>(
        plan: &Arc<Mutex<FaultPlan>>,
        mut call: impl FnMut(A) -> Result<R>,
    ) -> impl FnMut(A) -> Result<R> {
        let plan = Arc::clone(plan);
        move |arg| {
            Self::gate(&plan)?;
            call(arg)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn backoff_doubles_and_caps() {
        let p = FaultPolicy {
            backoff_base: Duration::from_millis(2),
            backoff_max: Duration::from_millis(10),
            ..FaultPolicy::default()
        };
        assert_eq!(p.backoff(0), Duration::from_millis(2));
        assert_eq!(p.backoff(1), Duration::from_millis(4));
        assert_eq!(p.backoff(2), Duration::from_millis(8));
        assert_eq!(p.backoff(3), Duration::from_millis(10));
        assert_eq!(p.backoff(60), Duration::from_millis(10)); // no overflow
    }

    #[test]
    fn validate_names_the_field() {
        let bad = FaultPolicy { outbox_cap: 0, ..FaultPolicy::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("outbox_cap"));
        let bad = FaultPolicy {
            backoff_max: Duration::from_millis(1),
            ..FaultPolicy::default()
        };
        assert!(bad.validate().unwrap_err().to_string().contains("backoff_max_ms"));
        assert!(FaultPolicy::default().validate().is_ok());
    }

    #[test]
    fn retry_succeeds_after_transient_faults() {
        let p = FaultPolicy {
            max_retries: 3,
            backoff_base: Duration::from_micros(10),
            ..FaultPolicy::default()
        };
        let calls = AtomicUsize::new(0);
        let out = call_with_retry(&p, None, "device", || {
            if calls.fetch_add(1, Ordering::SeqCst) < 2 {
                anyhow::bail!("transient");
            }
            Ok(7)
        });
        assert_eq!(out.result.unwrap(), 7);
        assert_eq!(out.attempts, 3);
        assert!(!out.timed_out);
    }

    #[test]
    fn retry_exhausts_boundedly() {
        let p = FaultPolicy {
            max_retries: 2,
            backoff_base: Duration::from_micros(10),
            ..FaultPolicy::default()
        };
        let out = call_with_retry::<()>(&p, None, "device", || anyhow::bail!("down"));
        assert_eq!(out.attempts, 3); // 1 + 2 retries
        assert!(!out.timed_out);
        let msg = format!("{:#}", out.result.unwrap_err());
        assert!(msg.contains("retries exhausted"), "{msg}");
        assert!(msg.contains("down"), "{msg}");
    }

    #[test]
    fn retry_catches_panics() {
        let p = FaultPolicy {
            max_retries: 1,
            backoff_base: Duration::from_micros(10),
            ..FaultPolicy::default()
        };
        let calls = AtomicUsize::new(0);
        let out = call_with_retry(&p, None, "device", || {
            if calls.fetch_add(1, Ordering::SeqCst) == 0 {
                panic!("device blew up");
            }
            Ok(1)
        });
        assert_eq!(out.result.unwrap(), 1);
        assert_eq!(out.attempts, 2);
    }

    #[test]
    fn deadline_stops_retrying() {
        let p = FaultPolicy {
            max_retries: 1000,
            backoff_base: Duration::from_millis(5),
            ..FaultPolicy::default()
        };
        let deadline = Instant::now() + Duration::from_millis(15);
        let out = call_with_retry::<()>(&p, Some(deadline), "device", || anyhow::bail!("down"));
        assert!(out.timed_out);
        assert!(out.attempts < 20, "deadline must bound attempts, got {}", out.attempts);
        assert!(format!("{:#}", out.result.unwrap_err()).contains("deadline exceeded"));
    }

    #[test]
    fn expired_deadline_skips_the_call() {
        let p = FaultPolicy::default();
        let out = call_with_retry::<()>(
            &p,
            Some(Instant::now() - Duration::from_millis(1)),
            "device",
            || panic!("must not be called"),
        );
        assert!(out.timed_out);
        assert_eq!(out.attempts, 0);
    }

    #[test]
    fn tracker_quarantines_and_recovers() {
        let mut t = FaultTracker::new(2);
        assert!(!t.record_fault("v1"));
        assert!(!t.is_quarantined("v1"));
        assert!(t.record_fault("v1"), "second fault crosses the budget");
        assert!(t.is_quarantined("v1"));
        assert!(!t.record_fault("v1"), "already quarantined: not 'newly'");
        t.record_success("v1");
        assert!(!t.is_quarantined("v1"));
    }

    #[test]
    fn tracker_budget_zero_disables() {
        let mut t = FaultTracker::new(0);
        for _ in 0..10 {
            assert!(!t.record_fault("v"));
        }
        assert!(!t.is_quarantined("v"));
    }

    #[test]
    fn fallback_walks_toward_cheaper_variants() {
        let ordered: Vec<String> =
            ["r0", "r64", "r128"].iter().map(|s| s.to_string()).collect();
        let mut t = FaultTracker::new(1);
        t.record_fault("r128");
        assert_eq!(t.fallback(&ordered, "r128"), Some("r64"));
        t.record_fault("r64");
        assert_eq!(t.fallback(&ordered, "r128"), Some("r0"));
        t.record_fault("r0");
        assert_eq!(t.fallback(&ordered, "r128"), None);
        assert_eq!(t.fallback(&ordered, "r0"), None, "nothing cheaper than r0");
    }

    #[test]
    fn fault_plan_is_deterministic_and_rate_accurate() {
        let run = |seed| {
            let mut plan = FaultPlan::new(seed, 0.2);
            let seq: Vec<Option<Injection>> = (0..2000).map(|_| plan.next()).collect();
            (seq, plan.injected_errors, plan.injected_delays, plan.injected_panics)
        };
        // note: `next()` itself doesn't bump the per-kind counters (wrap
        // does) — recount here
        let (a, ..) = run(7);
        let (b, ..) = run(7);
        assert_eq!(a, b, "same seed, same schedule");
        let (c, ..) = run(8);
        assert_ne!(a, c, "different seed, different schedule");
        let faults = a.iter().filter(|i| i.is_some()).count();
        let rate = faults as f64 / 2000.0;
        assert!((rate - 0.2).abs() < 0.04, "injection rate {rate} far from 0.2");
        let panics = a.iter().filter(|i| **i == Some(Injection::Panic)).count();
        assert!(panics * 4 < faults, "panics must be the minority injection");
    }

    #[test]
    fn fault_plan_wrap_counts_and_injects() {
        let plan = Arc::new(Mutex::new(FaultPlan::new(3, 1.0)));
        let mut wrapped = FaultPlan::wrap(&plan, |x: usize| Ok(x * 2));
        // rate 1.0: every call is an injection; errors dominate
        let mut errors = 0;
        for i in 0..50 {
            let r = catch_unwind(AssertUnwindSafe(|| wrapped(i)));
            match r {
                Ok(Ok(v)) => assert_eq!(v, i * 2), // delay path still executes
                Ok(Err(_)) => errors += 1,
                Err(_) => {} // injected panic
            }
        }
        let p = lock(&plan);
        assert_eq!(p.injected(), 50);
        assert_eq!(p.injected_errors, errors as u64);
        assert!(p.injected_errors > p.injected_panics);
        drop(p);

        let clean = Arc::new(Mutex::new(FaultPlan::new(3, 0.0)));
        let mut wrapped = FaultPlan::wrap(&clean, |x: usize| Ok(x + 1));
        for i in 0..20 {
            assert_eq!(wrapped(i).unwrap(), i + 1);
        }
        assert_eq!(lock(&clean).injected(), 0);
    }
}
