//! The dual serving loop: batch requests and stream sessions multiplexed
//! onto **one** device thread (DESIGN.md §9).
//!
//! PJRT handles are not `Send`, so a serving process has exactly one
//! thread that may touch the engine.  When a `"streaming"` block is
//! configured, that thread must drain two producers — the batch prep
//! stage ([`pipeline::spawn_prep`]) and the stream prep stage
//! ([`stream::spawn_stream_prep`]) — so both wrap their output into one
//! [`ReadyWork`] channel and the execute loop dispatches on the variant:
//!
//! ```text
//!  intake thread ──jobs──► batch prep ──┐ ReadyWork   execute thread
//!  (route+batch)           (slab fill)  ├───────────► Batch  -> respond
//!  stream clients ──────► stream prep ──┘  (depth 2)  Stream -> deliver
//!  (append events)        (decode steps)    ▲               │
//!                              ▲            └── recycle ────┘
//!                              └──── per-stage slab channels
//! ```
//!
//! Each prep stage keeps its own recycle channel and two slab buffers, so
//! the merge-while-execute overlap of both pipelines is preserved: batch
//! N+1's slab fill and the next decode step's assembly both proceed while
//! the device runs.  The shared ready channel has depth
//! [`SERVE_QUEUE_DEPTH`] (one slot per producer).
//!
//! Everything here is PJRT-free and generic over the device closures:
//! `tests/serve_stream.rs` drives the identical machinery with synthetic
//! devices, which is how the server wiring is pinned without hardware.

use std::collections::BTreeMap;
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, ensure, Result};

use super::faults::FaultContext;
use super::metrics::Metrics;
use super::pipeline::{self, PrepJob, ReadyBatch, VariantMeta};
use super::policy::MergePolicy;
use super::stream::{self, DecodeStep, StreamEvent};
use crate::merging::MergeSpec;
use crate::runtime::manifest::Manifest;
use crate::runtime::pool::WorkerPool;
use crate::streaming::StreamingConfig;
use crate::util::join_annotated;

/// One unit of device work, tagged by which pipeline produced it.
pub enum ReadyWork {
    /// a prepped one-shot forecast batch
    Batch(ReadyBatch),
    /// an assembled streaming decode step
    Stream(DecodeStep),
}

/// What startup resolved about the artifact that executes stream decode
/// steps (see [`resolve_stream_artifact`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamArtifact {
    /// variant (artifact) name executing decode steps
    pub variant: String,
    /// decode-step geometry: the artifact's batch capacity and `m`
    /// (context tokens — `m * d` values per row)
    pub meta: VariantMeta,
    /// the artifact consumes the `(capacity, m)` size array as a second
    /// input (so it can mask padding); plain artifacts get values only
    pub size_aware: bool,
}

/// Resolve which loaded artifact executes stream decode steps
/// (`"streaming"."variant"`, defaulting to the policy's first variant)
/// and check it is streaming-capable: input 0 a `(capacity, m * d)`
/// value slab (trailing dims flattened), optionally a second
/// `(capacity, m)` size input that consumes the decode step's size
/// array.  This is the startup **gate** that replaced the old
/// warn-and-ignore path: a configured `"streaming"` block with no loaded
/// streaming-capable artifact is an error, never a silent no-op.
pub fn resolve_stream_artifact(
    manifests: &BTreeMap<String, &Manifest>,
    policy: &MergePolicy,
    scfg: &StreamingConfig,
) -> Result<StreamArtifact> {
    ensure!(
        !policy.variants.is_empty(),
        "streaming serve needs at least one loaded variant"
    );
    let variant = match &scfg.variant {
        Some(v) => v.clone(),
        None => policy.variants[0].name.clone(),
    };
    let manifest = manifests.get(&variant).ok_or_else(|| {
        anyhow!(
            "the \"streaming\" block needs a loaded streaming-capable artifact, but \
             variant {variant:?} is not among the loaded variants {:?} — name one via \
             \"streaming\".\"variant\" or drop the block for batch-only serving",
            policy.variant_names()
        )
    })?;
    let inputs = &manifest.inputs;
    // a degenerate manifest gets its own named error rather than falling
    // through to a confusing dims complaint about a defaulted shape
    ensure!(
        !inputs.is_empty(),
        "artifact {variant}: stream decode artifact has no inputs — not streaming-capable"
    );
    ensure!(
        inputs[0].shape.len() >= 2,
        "artifact {variant}: input 0 shape {:?} is not a (batch, context) slab — not \
         streaming-capable",
        inputs[0].shape
    );
    let capacity = manifest.batch();
    let row_elems: usize = inputs[0].shape[1..].iter().product();
    ensure!(
        inputs[0].shape[0] == capacity && row_elems >= 1,
        "artifact {variant}: input 0 shape {:?} disagrees with its batch capacity \
         {capacity} — not streaming-capable",
        inputs[0].shape
    );
    ensure!(
        row_elems % scfg.d == 0,
        "artifact {variant}: {row_elems} values per row is not a whole number of \
         d = {} channels (streaming d must match the artifact's channel count)",
        scfg.d
    );
    let m = row_elems / scfg.d;
    ensure!(
        inputs.len() <= 2,
        "artifact {variant}: {} inputs — streaming decode feeds (values) or \
         (values, sizes) only",
        inputs.len()
    );
    let size_aware = inputs.len() == 2;
    if size_aware {
        let size_elems: usize = inputs[1].shape[1..].iter().product();
        ensure!(
            inputs[1].shape[0] == capacity && size_elems == m,
            "artifact {variant}: second input shape {:?} is not the (batch, m = {m}) \
             size array streaming decode produces",
            inputs[1].shape
        );
    }
    Ok(StreamArtifact { variant, meta: VariantMeta { capacity, m }, size_aware })
}

/// Depth of the shared ready channel: one slot per producing prep stage,
/// so neither pipeline can monopolize the device backlog.
pub const SERVE_QUEUE_DEPTH: usize = 2;

/// Run the batch **and** streaming pipelines until both input channels
/// close, executing all device work on the calling thread.
///
/// * `jobs` — batches from the intake stage; closing it winds down the
///   batch prep stage.
/// * `events` — stream append events; closing it (every sender dropped)
///   flushes remaining ready sessions and winds down the stream prep
///   stage.
/// * `execute_batch` / `execute_stream` — the device stages, running on
///   the calling thread; both may temporarily move the slab out of the
///   work item as long as a buffer is left behind for recycling.
/// * `deliver` — receives each session's rolling forecast.
/// * `faults` — the fault policy plus shared quarantine tracker
///   (DESIGN.md §10): device calls on both paths retry with backoff
///   under their deadlines; an exhausted batch answers every request
///   with a terminal error response, an exhausted decode step re-enqueues
///   its sessions' windows through the recycle path.  The loop keeps
///   serving through faults and returns once **both** prep stages have
///   exited.
#[allow(clippy::too_many_arguments)] // the serving composition root: two
// pipelines x (inputs, device closure) + shared infrastructure; every
// caller is a thin wrapper (server.rs, tests) and a builder would only
// move the argument list into a struct literal of the same size.
pub fn run_serve_stages<XB, XS, S>(
    jobs: Receiver<PrepJob>,
    events: Receiver<StreamEvent>,
    metas: BTreeMap<String, VariantMeta>,
    merge: MergeSpec,
    prep_slots: usize,
    stream_meta: VariantMeta,
    stream_cfg: StreamingConfig,
    pool: &'static WorkerPool,
    metrics: Arc<Mutex<Metrics>>,
    faults: FaultContext,
    mut execute_batch: XB,
    mut execute_stream: XS,
    mut deliver: S,
) -> Result<()>
where
    XB: FnMut(&mut ReadyBatch) -> Result<Vec<Vec<f32>>>,
    XS: FnMut(&mut DecodeStep) -> Result<Vec<Vec<f32>>>,
    S: FnMut(u64, Vec<f32>),
{
    faults.policy.validate()?;
    let (ready_tx, ready_rx) = sync_channel::<ReadyWork>(SERVE_QUEUE_DEPTH);
    let batch_prep = pipeline::spawn_prep(
        jobs,
        metas,
        merge,
        prep_slots,
        pool,
        Arc::clone(&metrics),
        ready_tx.clone(),
        ReadyWork::Batch,
    )?;
    let stream_prep = stream::spawn_stream_prep(
        events,
        stream_meta,
        stream_cfg,
        pool,
        Arc::clone(&metrics),
        faults.policy.clone(),
        ready_tx,
        ReadyWork::Stream,
    )?;
    for work in ready_rx.iter() {
        match work {
            ReadyWork::Batch(ready) => {
                let slab =
                    pipeline::execute_and_respond(&mut execute_batch, ready, &metrics, &faults);
                let _ = batch_prep.recycle.send(slab);
            }
            ReadyWork::Stream(mut step) => {
                stream::execute_and_deliver(
                    &mut execute_stream,
                    &mut deliver,
                    &mut step,
                    &faults.policy,
                    &metrics,
                );
                let _ = stream_prep.recycle.send(step);
            }
        }
    }
    drop(batch_prep.recycle);
    drop(stream_prep.recycle);
    join_annotated(batch_prep.join, "prep thread")?;
    join_annotated(stream_prep.join, "stream-prep thread")?;
    Ok(())
}
