//! Staged serving pipeline: host prep/merge overlapped with device
//! execution ("merge-while-execute").
//!
//! The PR 1 server ran route -> batch -> pad -> execute strictly serially
//! on one thread, so every millisecond of host-side work (slab padding,
//! token premerging) was a millisecond the accelerator sat idle.  This
//! module is the PJRT-free core of the staged replacement:
//!
//! ```text
//!  intake (server.rs)      prep stage (this module)       execute stage
//!  route + batch  ──jobs──► fill/premerge slab on the ──► model.execute +
//!  (deadline order)         WorkerPool                ▲   respond
//!                              ▲      │ ready (depth 1)│
//!                              └──────┴── slab recycle ┘
//!                                   (2 slabs in flight)
//! ```
//!
//! * [`HostPrep`] builds the padded `(capacity, m)` input slab for one
//!   batch.  Contexts longer than the artifact's `m` are **premerged** on
//!   the shared [`WorkerPool`]: the serving [`MergeSpec`] is derived per
//!   batch shape ([`MergeSpec::premerge_to`]), compiled once per
//!   `(len, m)` into a cached [`crate::merging::MergePlan`], and run over
//!   the batch — the serving-level use of the paper's compression:
//!   arbitrary-length requests meet a fixed-shape artifact.  A spec with
//!   [`MergeMode::Off`](crate::merging::MergeMode::Off) disables
//!   premerging (over-length requests are rejected, PR 1 behaviour).
//! * [`run_stages`] wires prep and execute together with a depth-1 ready
//!   channel and **two recycled slab buffers**, so batch N+1's padding and
//!   merging runs on the prep thread/pool while batch N executes on the
//!   device thread.  Steady state allocates nothing per batch beyond the
//!   response rows.
//!
//! The execute side is a closure, not a PJRT type: the real server
//! (`server.rs`, `pjrt` feature) passes `model.execute`, while
//! `benches/coordinator.rs` and `tests/coordinator_pipeline.rs` drive the
//! identical machinery with a synthetic device in the default offline
//! build — which is how the overlap gain in `BENCH_serving.json` is
//! measured without hardware.

use std::collections::BTreeMap;
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use super::faults::{call_with_retry, FaultContext};
use super::metrics::Metrics;
use super::{ForecastOutcome, ForecastRequest, ForecastResponse};
use crate::merging::{MergeMode, MergePlan, MergeSpec, PipelineResult};
use crate::obs::{recorder, Stage};
use crate::runtime::pool::WorkerPool;
use crate::util::{join_annotated, lock_ignore_poison as lock};

/// A routed request waiting for execution: request, enqueue time, response
/// channel.
pub type Pending = (ForecastRequest, Instant, Sender<ForecastResponse>);

/// What the execute stage needs to know about one loaded variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VariantMeta {
    /// static batch capacity of the compiled artifact
    pub capacity: usize,
    /// context length the artifact was compiled for
    pub m: usize,
}

/// The default host-premerge spec: enabled, schedule derived per batch
/// shape, locality [`MergeSpec::DEFAULT_K`].  Use [`MergeSpec::off`] to
/// disable premerging instead.
pub fn default_host_merge() -> MergeSpec {
    MergeSpec::fixed_r(Vec::new(), MergeSpec::DEFAULT_K)
}

/// One batch flushed by the intake stage, addressed to a variant.
pub struct PrepJob {
    pub variant: String,
    pub batch: Vec<Pending>,
}

/// A prepped batch: padded input slab plus the requests it answers.
pub struct ReadyBatch {
    pub variant: String,
    pub batch: Vec<Pending>,
    /// `(capacity, m)` row-major input slab (padding rows repeat the last
    /// real row, PR 1 convention)
    pub slab: Vec<f32>,
    /// real rows (the rest of the slab is padding)
    pub rows: usize,
    /// rows that went through host premerge
    pub premerged: usize,
}

/// Number of input slabs in flight between prep and execute — two buffers
/// double-buffer the pipeline: one filling, one executing.
pub const SLAB_BUFFERS: usize = 2;

/// Compiled premerge plans cached per `(len, m)`; bounded so a client
/// spraying distinct context lengths cannot grow scratch memory without
/// limit (each plan owns per-slot arenas sized to its `len`).
const PLAN_CACHE_CAP: usize = 16;

/// The prep stage's reusable state: the serving merge spec, compiled
/// premerge plans cached per `(context length, artifact m)` (one slot per
/// pool worker, so scratch stays warm), plus grow-only gather buffers —
/// steady-state prep of a batch allocates nothing.
///
/// Premerge executes compiled [`MergePlan`]s, so it inherits the kernel's
/// SIMD dispatch and cache-blocked matching (`merging::simd`, DESIGN.md
/// §11) with no state here; `Metrics::report()`'s `kernel:` line shows
/// which ISA this serving process premerges under.
pub struct HostPrep {
    merge: MergeSpec,
    slots: usize,
    plans: BTreeMap<(usize, usize), MergePlan>,
    /// insertion order of `plans` keys (FIFO eviction, like
    /// [`super::policy::EntropyCache`]): a hot shape is not evicted just
    /// because its key sorts first
    plan_fifo: std::collections::VecDeque<(usize, usize)>,
    ctx: Vec<f32>,
    ones: Vec<f32>,
    outs: Vec<PipelineResult>,
    /// merge telemetry of the most recent `prep_into` — see
    /// [`HostPrep::last_merge_telemetry`]
    last_merge: (usize, usize, usize),
}

impl HostPrep {
    pub fn new(slots: usize, merge: MergeSpec) -> HostPrep {
        HostPrep {
            merge,
            slots: slots.max(1),
            plans: BTreeMap::new(),
            plan_fifo: std::collections::VecDeque::new(),
            ctx: Vec::new(),
            ones: Vec::new(),
            outs: Vec::new(),
            last_merge: (0, 0, 0),
        }
    }

    /// The serving merge spec this prep stage premerges with.
    pub fn merge_spec(&self) -> &MergeSpec {
        &self.merge
    }

    /// Merge telemetry of the most recent successful [`HostPrep::prep_into`]:
    /// `(tokens entering premerge, tokens after, merge layers run)`,
    /// summed over the batch rows.  A batch that needed no premerge
    /// reports `in == out` with 0 layers, so every served batch yields a
    /// compression sample (`Metrics::record_compression`).
    pub fn last_merge_telemetry(&self) -> (usize, usize, usize) {
        self.last_merge
    }

    /// Fill `slab` with the padded `(capacity, m)` input for `batch`,
    /// premerging over-length contexts on `pool`.  Returns the number of
    /// premerged rows.  On error the slab contents are unspecified but the
    /// buffer is intact (the caller recycles it).
    pub fn prep_into(
        &mut self,
        pool: &WorkerPool,
        batch: &[Pending],
        meta: &VariantMeta,
        slab: &mut Vec<f32>,
    ) -> Result<usize> {
        let n = batch.len();
        let (capacity, m) = (meta.capacity, meta.m);
        ensure!(n > 0 && n <= capacity, "bad batch size {n} (capacity {capacity})");
        let len = batch[0].0.context.len();
        for (req, _, _) in batch {
            ensure!(
                req.context.len() == len,
                "ragged batch: context {} vs {len}",
                req.context.len()
            );
        }
        slab.clear();
        slab.reserve(capacity * m);
        let premerged = if len == m {
            for (req, _, _) in batch {
                slab.extend_from_slice(&req.context);
            }
            self.last_merge = (n * m, n * m, 0);
            0
        } else if len > m && !self.merge.is_off() {
            let HostPrep { merge, slots, plans, plan_fifo, ctx, ones, outs, .. } = self;
            if plans.len() >= PLAN_CACHE_CAP && !plans.contains_key(&(len, m)) {
                // evict the oldest entry, not the whole cache: a rotation
                // through cap+1 recurring shapes must not recompile every
                // plan, and a hot shape must not be evicted by key order
                if let Some(old) = plan_fifo.pop_front() {
                    plans.remove(&old);
                }
            }
            let plan = match plans.entry((len, m)) {
                std::collections::btree_map::Entry::Occupied(e) => e.into_mut(),
                std::collections::btree_map::Entry::Vacant(e) => {
                    let compiled =
                        merge.premerge_to(len, m)?.compile(len, 1)?.with_slots(*slots);
                    plan_fifo.push_back((len, m));
                    e.insert(compiled)
                }
            };
            ctx.clear();
            for (req, _, _) in batch {
                ctx.extend_from_slice(&req.context);
            }
            ones.clear();
            ones.resize(n * len, 1.0);
            plan.run_batch_into(pool, ctx, ones, n, outs);
            let (mut tokens_in, mut tokens_out) = (0usize, 0usize);
            for out in outs.iter().take(n) {
                ensure!(
                    out.sizes.len() == m,
                    "premerge produced {} tokens, wanted {m}",
                    out.sizes.len()
                );
                tokens_in += out.tokens_in();
                tokens_out += out.tokens_out();
                slab.extend_from_slice(&out.tokens);
            }
            let layers = outs.first().map_or(0, |o| o.layers());
            self.last_merge = (tokens_in, tokens_out, layers);
            n
        } else {
            bail!(
                "context length {len} != artifact m={m}{}",
                if len > m { " (host premerge disabled)" } else { "" }
            );
        };
        // Pad short batches by repeating the last real row (discarded on
        // the way out).
        for _ in n..capacity {
            slab.extend_from_within((n - 1) * m..n * m);
        }
        debug_assert_eq!(slab.len(), capacity * m);
        Ok(premerged)
    }
}

/// The spawned half of the batch pipeline: the prep thread's handle plus
/// the recycle channel the execute side returns slab buffers through.
/// Produced by [`spawn_prep`].
pub struct PrepStage {
    /// send executed slabs back for buffer recycling
    pub recycle: Sender<Vec<f32>>,
    /// the prep thread (exits when the job channel closes or the ready
    /// channel is dropped)
    pub join: thread::JoinHandle<()>,
}

/// Spawn the batch-prep thread: it pads/premerges each job into a slab
/// and sends the [`ReadyBatch`] through `ready_tx` (mapped by `wrap`, so
/// the batch and stream pipelines can share one ready channel — see
/// [`super::serve_loop::run_serve_stages`]).  [`run_stages`] is the
/// single-pipeline composition of this plus an execute loop.  A batch
/// prep cannot serve — unknown variant, ragged/over-length contexts —
/// gets terminal [`ForecastOutcome::Failed`] responses (and a `failed`
/// metrics count), never a silently dropped response channel.
// One arg over clippy's limit: the stage wiring (channels + wrap) and the
// shared metrics are each irreducible here.
#[allow(clippy::too_many_arguments)]
pub fn spawn_prep<T, W>(
    jobs: Receiver<PrepJob>,
    metas: BTreeMap<String, VariantMeta>,
    merge: MergeSpec,
    prep_slots: usize,
    pool: &'static WorkerPool,
    metrics: Arc<Mutex<Metrics>>,
    ready_tx: SyncSender<T>,
    wrap: W,
) -> Result<PrepStage>
where
    T: Send + 'static,
    W: Fn(ReadyBatch) -> T + Send + 'static,
{
    merge.validate()?;
    // The prep stage derives the premerge schedule per (context length,
    // artifact m); a spec carrying its own schedule or threshold would be
    // silently discarded, so only Off and the schedule-free fixed template
    // are meaningful here.
    ensure!(
        match &merge.mode {
            MergeMode::Off => true,
            MergeMode::FixedR { schedule } => schedule.is_empty(),
            MergeMode::Dynamic { .. } => false,
        },
        "serving merge spec must be Off or a schedule-free FixedR template \
         (the premerge schedule is derived per request shape)"
    );
    let (slab_tx, slab_rx) = std::sync::mpsc::channel::<Vec<f32>>();
    for _ in 0..SLAB_BUFFERS {
        let _ = slab_tx.send(Vec::new());
    }
    let prep_slab_tx = slab_tx.clone();
    let join = thread::Builder::new()
        .name("tomers-prep".into())
        .spawn(move || {
            let mut hp = HostPrep::new(prep_slots, merge);
            while let Ok(job) = jobs.recv() {
                let meta = match metas.get(&job.variant) {
                    Some(meta) => meta,
                    None => {
                        eprintln!("prep: unknown variant {} — failing batch", job.variant);
                        lock(&metrics).record_failed(job.batch.len());
                        respond_terminal(
                            job.batch,
                            &job.variant,
                            0,
                            ForecastOutcome::Failed(format!(
                                "unknown variant {}",
                                job.variant
                            )),
                        );
                        continue;
                    }
                };
                let mut slab = match slab_rx.recv() {
                    Ok(s) => s,
                    Err(_) => return, // execute stage gone
                };
                let t_prep = Instant::now();
                match hp.prep_into(pool, &job.batch, meta, &mut slab) {
                    Ok(premerged) => {
                        let prep_dur = t_prep.elapsed();
                        let rows = job.batch.len();
                        let leader = job.batch.first().map_or(0, |(r, _, _)| r.id);
                        let (tokens_in, tokens_out, layers) = hp.last_merge_telemetry();
                        {
                            let mut mx = lock(&metrics);
                            for (_, t0, _) in &job.batch {
                                let wait = t_prep.saturating_duration_since(*t0);
                                mx.record_stage(Stage::QueueWait, wait.as_secs_f64());
                            }
                            mx.record_stage(Stage::Prep, prep_dur.as_secs_f64());
                            mx.record_compression(
                                &job.variant,
                                tokens_in,
                                tokens_out,
                                layers,
                            );
                        }
                        if let Some((_, t0, _)) = job.batch.first() {
                            let wait = t_prep.saturating_duration_since(*t0);
                            recorder()
                                .record(leader, Stage::QueueWait, 0, *t0, wait, rows as u32);
                        }
                        recorder()
                            .record(leader, Stage::Prep, 0, t_prep, prep_dur, premerged as u32);
                        let ready = ReadyBatch {
                            variant: job.variant,
                            batch: job.batch,
                            slab,
                            rows,
                            premerged,
                        };
                        if ready_tx.send(wrap(ready)).is_err() {
                            return;
                        }
                    }
                    Err(e) => {
                        eprintln!("prep failed on {}: {e:#}", job.variant);
                        let _ = prep_slab_tx.send(slab);
                        lock(&metrics).record_failed(job.batch.len());
                        respond_terminal(
                            job.batch,
                            &job.variant,
                            0,
                            ForecastOutcome::Failed(format!("prep failed: {e:#}")),
                        );
                    }
                }
            }
        })
        .map_err(|e| anyhow!("spawning prep thread: {e}"))?;
    Ok(PrepStage { recycle: slab_tx, join })
}

/// Send a terminal non-delivered response to every request of a batch —
/// the fault path's replacement for silently dropping response channels:
/// a `submit()` receiver always observes exactly one terminal response.
pub(crate) fn respond_terminal(
    batch: Vec<Pending>,
    variant: &str,
    batch_size: usize,
    outcome: ForecastOutcome,
) {
    for (req, t0, rtx) in batch {
        let _ = rtx.send(ForecastResponse {
            id: req.id,
            forecast: Vec::new(),
            variant: variant.to_string(),
            latency: t0.elapsed().as_secs_f64(),
            batch_size,
            outcome: outcome.clone(),
        });
    }
}

/// Execute one prepped batch and send the responses — the execute-stage
/// body shared by [`run_stages`] and the dual serving loop.  Returns the
/// slab buffer for recycling, whatever happened.
///
/// Fault semantics (DESIGN.md §10): requests already past
/// `faults.request_deadline` get a terminal `DeadlineExceeded` response
/// (without device work if the whole batch expired); the device call is
/// retried with exponential backoff inside the earliest live request's
/// deadline; an exhausted batch gets terminal `Failed` responses and
/// counts one fault against the variant's quarantine budget.  Metrics are
/// recorded **before** the responses go out, so a client that drains its
/// responses and immediately asks for a report sees this batch.
pub(crate) fn execute_and_respond<X>(
    execute: &mut X,
    ready: ReadyBatch,
    metrics: &Mutex<Metrics>,
    faults: &FaultContext,
) -> Vec<f32>
where
    X: FnMut(&mut ReadyBatch) -> Result<Vec<Vec<f32>>>,
{
    let mut ready = ready;
    let policy = &faults.policy;
    let now = Instant::now();
    // requests already past their deadline time out without device work;
    // the live ones' earliest deadline bounds the retry window
    let mut expired = vec![false; ready.batch.len()];
    let mut batch_deadline: Option<Instant> = None;
    if let Some(limit) = policy.request_deadline {
        for (i, (_, t0, _)) in ready.batch.iter().enumerate() {
            let d = *t0 + limit;
            if d <= now {
                expired[i] = true;
            } else {
                batch_deadline = Some(batch_deadline.map_or(d, |b| b.min(d)));
            }
        }
        if expired.iter().all(|&e| e) {
            let ReadyBatch { variant, batch, slab, rows, .. } = ready;
            lock(metrics).record_timeouts(batch.len());
            respond_terminal(batch, &variant, rows, ForecastOutcome::DeadlineExceeded);
            return slab;
        }
    }
    let t_exec = Instant::now();
    let out =
        call_with_retry(policy, batch_deadline, "device execute", || execute(&mut ready));
    let exec_dur = t_exec.elapsed();
    let ReadyBatch { variant, batch, slab, rows, .. } = ready;
    let leader = batch.first().map_or(0, |(r, _, _)| r.id);
    recorder().record(leader, Stage::Exec, 0, t_exec, exec_dur, out.attempts as u32);
    {
        let mut mx = lock(metrics);
        mx.record_stage(Stage::Exec, exec_dur.as_secs_f64());
        if out.attempts > 1 {
            mx.record_exec_retries(out.attempts - 1);
        }
    }
    match out.result {
        Ok(forecasts) if forecasts.len() >= rows => {
            lock(&faults.tracker).record_success(&variant);
            let latencies: Vec<f64> =
                batch.iter().map(|(_, t0, _)| t0.elapsed().as_secs_f64()).collect();
            let delivered: Vec<f64> = latencies
                .iter()
                .zip(&expired)
                .filter(|(_, &e)| !e)
                .map(|(l, _)| *l)
                .collect();
            {
                let mut mx = lock(metrics);
                if !delivered.is_empty() {
                    mx.record_batch(&variant, delivered.len(), &delivered);
                }
                mx.record_timeouts(rows - delivered.len());
            }
            let t_resp = Instant::now();
            for (i, (((req, _, rtx), forecast), latency)) in
                batch.into_iter().zip(forecasts).zip(latencies).enumerate()
            {
                let (forecast, outcome) = if expired[i] {
                    (Vec::new(), ForecastOutcome::DeadlineExceeded)
                } else {
                    (forecast, ForecastOutcome::Delivered)
                };
                let _ = rtx.send(ForecastResponse {
                    id: req.id,
                    forecast,
                    variant: variant.clone(),
                    latency,
                    batch_size: rows,
                    outcome,
                });
            }
            let resp_dur = t_resp.elapsed();
            recorder().record(leader, Stage::Respond, 0, t_resp, resp_dur, rows as u32);
            lock(metrics).record_stage(Stage::Respond, resp_dur.as_secs_f64());
        }
        Ok(forecasts) => {
            let reason = format!(
                "execute on {variant} returned {} rows for {rows} requests",
                forecasts.len()
            );
            eprintln!("{reason} — failing batch");
            fail_batch(batch, &variant, rows, reason, false, metrics, faults);
        }
        Err(e) => {
            let reason = format!("{e:#}");
            eprintln!("batch execution failed on {variant}: {reason}");
            fail_batch(batch, &variant, rows, reason, out.timed_out, metrics, faults);
        }
    }
    slab
}

/// Terminal-failure bookkeeping shared by the execute error paths: fault
/// metrics, the variant's quarantine budget, and terminal responses
/// (`DeadlineExceeded` when the deadline — not the device — gave up).
fn fail_batch(
    batch: Vec<Pending>,
    variant: &str,
    rows: usize,
    reason: String,
    timed_out: bool,
    metrics: &Mutex<Metrics>,
    faults: &FaultContext,
) {
    {
        let mut mx = lock(metrics);
        mx.record_exec_fault();
        if timed_out {
            mx.record_timeouts(batch.len());
        } else {
            mx.record_failed(batch.len());
        }
    }
    if lock(&faults.tracker).record_fault(variant) {
        eprintln!(
            "variant {variant} quarantined after {} consecutive faults — routing will \
             downgrade to a cheaper variant",
            faults.policy.variant_fault_budget
        );
    }
    let outcome = if timed_out {
        ForecastOutcome::DeadlineExceeded
    } else {
        ForecastOutcome::Failed(reason)
    };
    respond_terminal(batch, variant, rows, outcome);
}

/// Run the prep + execute stages until the job channel closes.
///
/// * `jobs` — batches from the intake stage (routing + deadline-ordered
///   dynamic batching).
/// * `merge` — the serving [`MergeSpec`] for host premerge of over-length
///   contexts ([`MergeSpec::off`] rejects them instead).
/// * `execute` — the device stage, running **on the calling thread** (PJRT
///   handles are not `Send`): takes a prepped batch (mutably, so it may
///   temporarily move the slab out — e.g. into a host tensor — as long as
///   it leaves *a* buffer behind for recycling), returns one forecast row
///   per real request.
///
/// A prep failure or an exhausted execute failure fails that batch with
/// terminal responses ([`ForecastOutcome::Failed`] /
/// [`ForecastOutcome::DeadlineExceeded`] — see [`execute_and_respond`])
/// and the pipeline keeps serving.  When the server also runs stream
/// sessions it uses [`super::serve_loop::run_serve_stages`], which
/// multiplexes this pipeline with the streaming decode stages on one
/// device thread.
// One arg over clippy's limit: the fault context joined an already-full
// stage signature; bundling it with metrics would couple unrelated types.
#[allow(clippy::too_many_arguments)]
pub fn run_stages<X>(
    jobs: Receiver<PrepJob>,
    metas: BTreeMap<String, VariantMeta>,
    merge: MergeSpec,
    prep_slots: usize,
    pool: &'static WorkerPool,
    metrics: Arc<Mutex<Metrics>>,
    faults: FaultContext,
    mut execute: X,
) -> Result<()>
where
    X: FnMut(&mut ReadyBatch) -> Result<Vec<Vec<f32>>>,
{
    faults.policy.validate()?;
    let (ready_tx, ready_rx) = sync_channel::<ReadyBatch>(1);
    let prep = spawn_prep(
        jobs,
        metas,
        merge,
        prep_slots,
        pool,
        Arc::clone(&metrics),
        ready_tx,
        |b| b,
    )?;
    for ready in ready_rx.iter() {
        let slab = execute_and_respond(&mut execute, ready, &metrics, &faults);
        let _ = prep.recycle.send(slab);
    }
    drop(prep.recycle);
    join_annotated(prep.join, "prep thread")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_host_merge_is_enabled() {
        let spec = default_host_merge();
        assert!(!spec.is_off());
        assert!(spec.k >= 1);
        assert!(spec.validate().is_ok());
        // template derives a concrete, compilable premerge spec
        let derived = spec.premerge_to(2048, 512).unwrap();
        assert!(derived.compile(2048, 1).is_ok());
    }

    #[test]
    fn plan_cache_stays_bounded() {
        let pool = WorkerPool::new(2);
        let mut hp = HostPrep::new(2, default_host_merge());
        let meta = VariantMeta { capacity: 1, m: 8 };
        let mut slab = Vec::new();
        for len in 0..PLAN_CACHE_CAP + 5 {
            let ctx: Vec<f32> = (0..16 + 2 * len).map(|i| i as f32 * 0.25).collect();
            let (rtx, _rrx) = std::sync::mpsc::channel();
            let req = ForecastRequest { id: len as u64, context: ctx };
            let batch = vec![(req, Instant::now(), rtx)];
            hp.prep_into(&pool, &batch, &meta, &mut slab).expect("prep");
            assert_eq!(slab.len(), meta.capacity * meta.m);
            assert!(hp.plans.len() <= PLAN_CACHE_CAP, "cache grew past the cap");
        }
    }
}
