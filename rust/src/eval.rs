//! Evaluation: forecasting/classification metrics, Chronos dequantization,
//! and the paper's Pareto selection rules.

use anyhow::Result;

use crate::tensor::Tensor;

/// Mean squared error over two equal-shaped f32 tensors.
pub fn mse(pred: &Tensor, target: &Tensor) -> Result<f64> {
    let (p, t) = (pred.f32s()?, target.f32s()?);
    anyhow::ensure!(p.len() == t.len(), "mse: length mismatch");
    Ok(p.iter().zip(t).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>() / p.len() as f64)
}

/// Mean absolute error.
pub fn mae(pred: &Tensor, target: &Tensor) -> Result<f64> {
    let (p, t) = (pred.f32s()?, target.f32s()?);
    anyhow::ensure!(p.len() == t.len(), "mae: length mismatch");
    Ok(p.iter().zip(t).map(|(a, b)| (a - b).abs() as f64).sum::<f64>() / p.len() as f64)
}

/// Classification accuracy from logits (b, n_classes) vs labels (b,).
pub fn accuracy(logits: &Tensor, labels: &[i32]) -> Result<f64> {
    let shape = logits.shape();
    anyhow::ensure!(shape.len() == 2 && shape[0] == labels.len(), "accuracy shapes");
    let n_classes = shape[1];
    let data = logits.f32s()?;
    let mut correct = 0usize;
    for (i, &label) in labels.iter().enumerate() {
        let row = &data[i * n_classes..(i + 1) * n_classes];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        if pred == label as usize {
            correct += 1;
        }
    }
    Ok(correct as f64 / labels.len() as f64)
}

/// Dequantize Chronos logits (b, p, vocab) + scales (b,) to values (b, p)
/// via greedy argmax through the uniform bin centres (mirror of
/// `models/chronos.py::dequantize`).
pub fn chronos_dequantize(logits: &Tensor, scales: &Tensor, vocab: usize, clip: f64) -> Result<Tensor> {
    let shape = logits.shape().to_vec();
    anyhow::ensure!(shape.len() == 3 && shape[2] == vocab, "logits shape {:?}", shape);
    let (b, p) = (shape[0], shape[1]);
    let data = logits.f32s()?;
    let sc = scales.f32s()?;
    let mut out = Vec::with_capacity(b * p);
    for i in 0..b {
        for j in 0..p {
            let row = &data[(i * p + j) * vocab..(i * p + j + 1) * vocab];
            let id = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            let center = (id as f64 / (vocab - 1) as f64) * 2.0 * clip - clip;
            out.push((center * sc[i] as f64) as f32);
        }
    }
    Tensor::from_f32(&[b, p], out)
}

/// One evaluated operating point of a (model, merge-config) pair.  The
/// merge side of the pair is a [`crate::merging::MergeSpec`] realized in
/// the artifact; [`OperatingPoint::for_spec`] derives the conventional
/// `name__r<N>` label from one so the bench suites and the serving
/// config name variants identically.
#[derive(Clone, Debug)]
pub struct OperatingPoint {
    pub name: String,
    pub mse: f64,
    /// throughput relative to some fixed workload (samples/s)
    pub throughput: f64,
}

impl OperatingPoint {
    /// Label an operating point after the spec its artifact realizes
    /// (`<identity>__r<total_r>`, the convention the serving policy's
    /// variant names and the artifact filenames follow).
    pub fn for_spec(
        identity: &str,
        spec: &crate::merging::MergeSpec,
        mse: f64,
        throughput: f64,
    ) -> OperatingPoint {
        OperatingPoint { name: format!("{identity}__r{}", spec.total_r()), mse, throughput }
    }

    pub fn accel(&self, reference: &OperatingPoint) -> f64 {
        self.throughput / reference.throughput
    }
    pub fn mse_delta_pct(&self, reference: &OperatingPoint) -> f64 {
        100.0 * (self.mse - reference.mse) / reference.mse
    }
}

/// §5.1 selection: the *fastest* merging trial whose validation MSE is
/// within `mse_budget` (absolute, paper: 0.01) of the no-merging reference;
/// falls back to the reference when none qualifies ("we report results
/// without token merging" — paper).
pub fn select_fastest_within<'a>(
    reference: &'a OperatingPoint,
    candidates: &'a [OperatingPoint],
    mse_budget: f64,
) -> &'a OperatingPoint {
    candidates
        .iter()
        .filter(|c| c.mse <= reference.mse + mse_budget)
        .max_by(|a, b| a.throughput.total_cmp(&b.throughput))
        .filter(|c| c.throughput > reference.throughput)
        .unwrap_or(reference)
}

/// Table 2 "best" objective: the candidate with the lowest MSE.
pub fn select_best_mse<'a>(
    reference: &'a OperatingPoint,
    candidates: &'a [OperatingPoint],
) -> &'a OperatingPoint {
    candidates
        .iter()
        .chain(std::iter::once(reference))
        .min_by(|a, b| a.mse.total_cmp(&b.mse))
        .unwrap()
}

/// Table 2 "fastest" objective: fastest candidate with MSE within
/// `rel_budget` (paper: 3%) of the reference.
pub fn select_fastest_rel<'a>(
    reference: &'a OperatingPoint,
    candidates: &'a [OperatingPoint],
    rel_budget: f64,
) -> &'a OperatingPoint {
    candidates
        .iter()
        .filter(|c| c.mse <= reference.mse * (1.0 + rel_budget))
        .max_by(|a, b| a.throughput.total_cmp(&b.throughput))
        .unwrap_or(reference)
}

/// Pareto front (min MSE, max throughput) of a candidate set.
pub fn pareto_front(points: &[OperatingPoint]) -> Vec<&OperatingPoint> {
    let mut front: Vec<&OperatingPoint> = Vec::new();
    for p in points {
        let dominated = points.iter().any(|q| {
            (q.mse < p.mse && q.throughput >= p.throughput)
                || (q.mse <= p.mse && q.throughput > p.throughput)
        });
        if !dominated {
            front.push(p);
        }
    }
    front.sort_by(|a, b| a.mse.total_cmp(&b.mse));
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(name: &str, mse: f64, thr: f64) -> OperatingPoint {
        OperatingPoint { name: name.into(), mse, throughput: thr }
    }

    #[test]
    fn mse_mae_basic() {
        let a = Tensor::from_f32(&[4], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_f32(&[4], vec![1., 2., 3., 6.]).unwrap();
        assert!((mse(&a, &b).unwrap() - 1.0).abs() < 1e-12);
        assert!((mae(&a, &b).unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn accuracy_counts_argmax() {
        let logits =
            Tensor::from_f32(&[2, 2], vec![0.9, 0.1, 0.2, 0.8]).unwrap();
        assert_eq!(accuracy(&logits, &[0, 1]).unwrap(), 1.0);
        assert_eq!(accuracy(&logits, &[1, 1]).unwrap(), 0.5);
    }

    #[test]
    fn dequantize_inverts_bins() {
        // vocab 3, clip 1: centres -1, 0, 1
        let logits = Tensor::from_f32(&[1, 2, 3], vec![9., 0., 0., 0., 0., 9.]).unwrap();
        let scales = Tensor::from_f32(&[1], vec![2.0]).unwrap();
        let v = chronos_dequantize(&logits, &scales, 3, 1.0).unwrap();
        assert_eq!(v.f32s().unwrap(), &[-2.0, 2.0]);
    }

    #[test]
    fn for_spec_labels_follow_the_artifact_convention() {
        use crate::merging::MergeSpec;
        let p = OperatingPoint::for_spec("chronos_s", &MergeSpec::single(64, 8), 0.4, 120.0);
        assert_eq!(p.name, "chronos_s__r64");
        let p = OperatingPoint::for_spec("fc_tf_L2", &MergeSpec::off(), 0.4, 120.0);
        assert_eq!(p.name, "fc_tf_L2__r0");
    }

    #[test]
    fn selection_rules_match_paper() {
        let reference = op("r0", 0.40, 100.0);
        let cands = vec![op("r16", 0.405, 180.0), op("r32", 0.42, 260.0), op("r64", 0.52, 400.0)];
        // fastest within +0.01 absolute: r16 qualifies, r32 (+0.02) does not
        assert_eq!(select_fastest_within(&reference, &cands, 0.01).name, "r16");
        // best MSE: reference itself here
        assert_eq!(select_best_mse(&reference, &cands).name, "r0");
        // fastest within +3% relative: 0.40*1.03 = 0.412 -> r16
        assert_eq!(select_fastest_rel(&reference, &cands, 0.03).name, "r16");
        // no qualifying candidate -> reference
        assert_eq!(select_fastest_within(&reference, &cands[2..], 0.01).name, "r0");
    }

    #[test]
    fn pareto_front_filters_dominated() {
        let pts = vec![op("a", 0.4, 100.0), op("b", 0.38, 150.0), op("c", 0.5, 120.0)];
        let front = pareto_front(&pts);
        // b dominates a and c entirely
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].name, "b");
    }
}
