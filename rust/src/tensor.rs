//! Host tensor type shared by the runtime, data generators and metrics.
//!
//! Two dtypes are enough for the whole system (f32 activations/weights,
//! i32 token ids / labels / slot maps); conversions to/from `xla::Literal`
//! live in `runtime::engine`.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        if shape.iter().product::<usize>() != data.len() {
            bail!("shape {:?} does not match data len {}", shape, data.len());
        }
        Ok(Tensor::F32 { shape: shape.to_vec(), data })
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Result<Tensor> {
        if shape.iter().product::<usize>() != data.len() {
            bail!("shape {:?} does not match data len {}", shape, data.len());
        }
        Ok(Tensor::I32 { shape: shape.to_vec(), data })
    }

    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![x] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "f32",
            Tensor::I32 { .. } => "i32",
        }
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor, got {}", self.dtype()),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor, got {}", self.dtype()),
        }
    }

    pub fn f32s_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor, got {}", self.dtype()),
        }
    }

    /// Row `i` of a rank>=1 tensor as a flat slice (outermost axis index).
    pub fn row_f32(&self, i: usize) -> Result<&[f32]> {
        let shape = self.shape();
        if shape.is_empty() {
            bail!("scalar has no rows");
        }
        let row = self.len() / shape[0];
        Ok(&self.f32s()?[i * row..(i + 1) * row])
    }

    /// Reshape in place (same element count).
    pub fn reshape(&mut self, shape: &[usize]) -> Result<()> {
        if shape.iter().product::<usize>() != self.len() {
            bail!("reshape {:?} -> {:?} mismatch", self.shape(), shape);
        }
        match self {
            Tensor::F32 { shape: s, .. } | Tensor::I32 { shape: s, .. } => {
                *s = shape.to_vec()
            }
        }
        Ok(())
    }

    /// Stack rank-R tensors along a new outermost axis.
    pub fn stack(rows: &[Tensor]) -> Result<Tensor> {
        if rows.is_empty() {
            bail!("cannot stack zero tensors");
        }
        let inner = rows[0].shape().to_vec();
        let mut shape = vec![rows.len()];
        shape.extend_from_slice(&inner);
        match &rows[0] {
            Tensor::F32 { .. } => {
                let mut data = Vec::with_capacity(shape.iter().product());
                for r in rows {
                    if r.shape() != inner.as_slice() {
                        bail!("ragged stack: {:?} vs {:?}", r.shape(), inner);
                    }
                    data.extend_from_slice(r.f32s()?);
                }
                Tensor::from_f32(&shape, data)
            }
            Tensor::I32 { .. } => {
                let mut data = Vec::with_capacity(shape.iter().product());
                for r in rows {
                    if r.shape() != inner.as_slice() {
                        bail!("ragged stack: {:?} vs {:?}", r.shape(), inner);
                    }
                    data.extend_from_slice(r.i32s()?);
                }
                Tensor::from_i32(&shape, data)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.row_f32(1).unwrap(), &[4., 5., 6.]);
        assert_eq!(t.dtype(), "f32");
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::from_f32(&[2, 2], vec![1.0]).is_err());
        assert!(Tensor::from_i32(&[3], vec![1, 2]).is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let mut t = Tensor::zeros_f32(&[4, 2]);
        t.reshape(&[2, 4]).unwrap();
        assert_eq!(t.shape(), &[2, 4]);
        assert!(t.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn stack_builds_batch() {
        let a = Tensor::from_f32(&[2], vec![1., 2.]).unwrap();
        let b = Tensor::from_f32(&[2], vec![3., 4.]).unwrap();
        let s = Tensor::stack(&[a, b]).unwrap();
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.f32s().unwrap(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn stack_rejects_ragged() {
        let a = Tensor::zeros_f32(&[2]);
        let b = Tensor::zeros_f32(&[3]);
        assert!(Tensor::stack(&[a, b]).is_err());
    }

    #[test]
    fn dtype_guards() {
        let t = Tensor::from_i32(&[2], vec![1, 2]).unwrap();
        assert!(t.f32s().is_err());
        assert!(t.i32s().is_ok());
    }
}
