//! FLOPs cost model (the paper's thop-equivalent, used for fig. 4 and the
//! hardware-independent acceleration numbers).
//!
//! Counts multiply-accumulates as 2 FLOPs.  The per-layer token counts come
//! from the merge schedule in each artifact's manifest, so the model prices
//! exactly the computation the compiled variant performs — including the
//! merging overhead itself (eq. 2 similarity cost + the averaging pass).

/// Architecture flavour of a transformer layer (matches `models/variants.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    Vanilla,
    Informer,
    Autoformer,
    Fedformer,
    Nonstationary,
}

impl Arch {
    pub fn parse(s: &str) -> Option<Arch> {
        Some(match s {
            "transformer" => Arch::Vanilla,
            "informer" => Arch::Informer,
            "autoformer" => Arch::Autoformer,
            "fedformer" => Arch::Fedformer,
            "nonstationary" => Arch::Nonstationary,
            _ => return None,
        })
    }
}

/// Dense layer: x (t, din) @ w (din, dout).
pub fn dense_flops(t: usize, din: usize, dout: usize) -> u64 {
    2 * t as u64 * din as u64 * dout as u64
}

/// Attention-mechanism FLOPs for one layer at `t` query tokens / `tk` key
/// tokens, model width `d` (QKV/out projections + the mechanism itself).
pub fn attention_flops(arch: Arch, t: usize, tk: usize, d: usize) -> u64 {
    let proj = dense_flops(t, d, d) + 2 * dense_flops(tk, d, d) + dense_flops(t, d, d);
    let mech = match arch {
        // full QK^T + AV
        Arch::Vanilla | Arch::Nonstationary => 2 * (2 * t as u64 * tk as u64 * d as u64),
        // ProbSparse: u = 5 ln t active queries attend
        Arch::Informer => {
            let u = ((5.0 * (t.max(2) as f64).ln()).ceil() as u64).min(t as u64);
            // scoring pass (all queries vs keys) + full attention for u queries
            2 * t as u64 * tk as u64 * d as u64 + 2 * u * tk as u64 * d as u64
        }
        // autocorrelation: 3 FFTs of length t over d channels (~ 5 t log t
        // real-FLOPs each) + top-c roll/aggregate
        Arch::Autoformer => {
            let fft = (5.0 * t as f64 * (t.max(2) as f64).log2()) as u64 * d as u64;
            let c = (2.0 * (t.max(2) as f64).ln()).ceil() as u64;
            3 * fft + 2 * c * t as u64 * d as u64
        }
        // frequency-enhanced: FFT + mode mixing + iFFT
        Arch::Fedformer => {
            let fft = (5.0 * t as f64 * (t.max(2) as f64).log2()) as u64 * d as u64;
            let modes = 16u64.min(t as u64 / 2 + 1);
            2 * fft + 6 * modes * d as u64
        }
    };
    proj + mech
}

/// GELU MLP: d -> hidden -> d.
pub fn mlp_flops(t: usize, d: usize, hidden: usize) -> u64 {
    dense_flops(t, d, hidden) + dense_flops(t, hidden, d)
}

/// Token-merging overhead at one layer: banded similarity (eq. 2) of
/// d-dim dot products + the averaging pass.
pub fn merge_flops(t: usize, k: usize, d: usize) -> u64 {
    let sims = crate::merging::similarity_complexity(t, k) as u64;
    sims * 2 * d as u64 + t as u64 * d as u64
}

/// Whole encoder stack given the per-layer token counts from the manifest
/// (`tokens[l]` tokens enter layer `l`; `tokens[l+1]` leave its merge).
pub fn encoder_flops(arch: Arch, tokens: &[usize], d: usize, hidden: usize, k_global: bool) -> u64 {
    let mut total = 0u64;
    for l in 0..tokens.len() - 1 {
        let t = tokens[l];
        let t_out = tokens[l + 1];
        total += attention_flops(arch, t, t, d);
        if t_out < t {
            let k = if k_global { t / 2 } else { 1 };
            total += merge_flops(t, k, d);
        }
        total += mlp_flops(t_out, d, hidden);
    }
    total
}

/// Decoder stack: causal self-attention (+ causal merge) + cross-attention
/// to `enc_t` tokens + MLP.
pub fn decoder_flops(tokens: &[usize], enc_t: usize, d: usize, hidden: usize) -> u64 {
    let mut total = 0u64;
    for l in 0..tokens.len() - 1 {
        let t = tokens[l];
        let t_out = tokens[l + 1];
        total += attention_flops(Arch::Vanilla, t, t, d);
        if t_out < t {
            total += merge_flops(t, 1, d);
        }
        total += attention_flops(Arch::Vanilla, t_out, enc_t, d);
        total += mlp_flops(t_out, d, hidden);
    }
    total
}

/// Hyena block: in/out projections + `order` FFT convs + gating.
pub fn hyena_flops(t: usize, d: usize, order: usize) -> u64 {
    let proj = dense_flops(t, d, (order + 1) * d) + dense_flops(t, d, d);
    let n = 2 * t;
    let fftconv = (5.0 * n as f64 * (n.max(2) as f64).log2()) as u64 * d as u64 * 3;
    proj + order as u64 * (fftconv + 2 * t as u64 * d as u64)
}

/// Mamba block: projections + depthwise conv + selective scan.
pub fn mamba_flops(t: usize, d: usize, d_inner: usize, d_state: usize, d_conv: usize) -> u64 {
    let proj = dense_flops(t, d, 2 * d_inner)
        + dense_flops(t, d_inner, 2 * d_state + 1)
        + dense_flops(t, 1, d_inner)
        + dense_flops(t, d_inner, d);
    let conv = 2 * t as u64 * d_inner as u64 * d_conv as u64;
    // scan: per step per channel per state: exp, 2 mul-add, dot with C
    let scan = 8 * t as u64 * d_inner as u64 * d_state as u64;
    proj + conv + scan
}

/// State-space classifier stack.
pub fn ssm_stack_flops(
    mamba: bool,
    tokens: &[usize],
    d: usize,
    d_inner: usize,
    d_state: usize,
    k: usize,
) -> u64 {
    let mut total = 0u64;
    for l in 0..tokens.len() - 1 {
        let t = tokens[l];
        total += if mamba {
            mamba_flops(t, d, d_inner, d_state, 4)
        } else {
            hyena_flops(t, d, 2)
        };
        if tokens[l + 1] < t {
            total += merge_flops(t, k, d);
        }
        if !mamba {
            total += mlp_flops(tokens[l + 1], d, 2 * d);
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_counts_macs_twice() {
        assert_eq!(dense_flops(10, 4, 8), 2 * 10 * 4 * 8);
    }

    #[test]
    fn merging_reduces_encoder_flops() {
        let full = encoder_flops(Arch::Vanilla, &[192, 192, 192], 64, 128, true);
        let merged = encoder_flops(Arch::Vanilla, &[192, 160, 128], 64, 128, true);
        assert!(merged < full);
    }

    #[test]
    fn halving_schedule_approaches_bound() {
        // With aggressive halving the FLOPs ratio should approach (but not
        // exceed) the B.1 bound for attention-dominated models.
        let l = 6usize;
        let t0 = 1024usize;
        let full: Vec<usize> = vec![t0; l + 1];
        let mut halved = vec![t0];
        for _ in 0..l {
            halved.push((halved.last().unwrap() / 2).max(2));
        }
        // widen d so attention dominates the MLP
        let f_full = encoder_flops(Arch::Vanilla, &full, 8, 8, true);
        let f_half = encoder_flops(Arch::Vanilla, &halved, 8, 8, true);
        let ratio = f_full as f64 / f_half as f64;
        let bound = crate::merging::speedup_bound(l as u32);
        assert!(ratio > 1.5, "ratio {ratio}");
        assert!(ratio <= bound * 1.45, "ratio {ratio} vs bound {bound}");
    }

    #[test]
    fn informer_cheaper_than_vanilla_at_long_t() {
        let t = 4096;
        assert!(
            attention_flops(Arch::Informer, t, t, 64) < attention_flops(Arch::Vanilla, t, t, 64)
        );
    }

    #[test]
    fn merge_overhead_linear_vs_quadratic() {
        let lin = merge_flops(16_000, 1, 64);
        let quad = merge_flops(16_000, 8_000, 64);
        // paper §5.4: local merging adds ~14% per block, global ~68%
        assert!(quad > 100 * lin);
    }

    #[test]
    fn ssm_flops_monotone_in_tokens() {
        let a = ssm_stack_flops(true, &[1024, 896, 768, 640, 512], 64, 128, 8, 1);
        let b = ssm_stack_flops(true, &[1024, 1024, 1024, 1024, 1024], 64, 128, 8, 1);
        assert!(a < b);
    }
}
