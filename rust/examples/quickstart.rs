//! Quickstart: load a compiled chronos-like forecaster, apply token
//! merging, and compare throughput against the unmerged model.
//!
//! Run after `make artifacts` (needs a real PJRT binding in
//! rust/vendor/xla):
//!     cargo run --release --offline --features pjrt --example quickstart

use anyhow::Result;
use tomers::data;
use tomers::merging::MergeSpec;
use tomers::runtime::Engine;
use tomers::tensor::Tensor;
use tomers::util::bench;

fn main() -> Result<()> {
    // 0. Host-side merging is one typed API: describe with a MergeSpec,
    //    compile once per shape, run many (DESIGN.md §2).  This is the
    //    same machinery the serving prep stage uses to premerge
    //    over-length contexts down to an artifact's context length.
    let spec = MergeSpec::fixed_r(Vec::new(), MergeSpec::DEFAULT_K); // serving template
    let mut plan = spec.premerge_to(768, 192)?.compile(768, 1)?;
    let long_context: Vec<f32> = (0..768).map(|i| (i as f32 * 0.02).sin()).collect();
    let premerged = plan.run(&long_context, &vec![1.0; 768]);
    println!(
        "host premerge: 768 raw -> {} tokens (per-layer token counts {:?})",
        premerged.sizes.len(),
        premerged.token_counts
    );

    // 1. The engine compiles HLO-text artifacts on the PJRT CPU client.
    let engine = Engine::new("artifacts")?;
    println!("platform: {}", engine.platform());

    // 2. Two variants of the *same* trained model (same weights file):
    //    r=0 (no merging) and r=128 (aggressive local merging).
    let baseline = engine.load_with_weights("chronos_s__r0")?;
    let merged = engine.load_with_weights("chronos_s__r128")?;
    println!(
        "token schedule without merging: {:?}",
        baseline.manifest.enc_tokens().unwrap()
    );
    println!(
        "token schedule with merging:    {:?}",
        merged.manifest.enc_tokens().unwrap()
    );

    // 3. A synthetic ETTh1-like context batch (batch size from the manifest).
    let b = baseline.manifest.batch();
    let m = baseline.manifest.inputs[0].shape[1];
    let series = data::generate(data::profile("etth1").unwrap(), m + 64, 7);
    let mut xs = Vec::with_capacity(b * m);
    for i in 0..b {
        let col = series.column(i % series.n_vars);
        xs.extend_from_slice(&col[..m]);
    }
    let x = Tensor::from_f32(&[b, m], xs)?;

    // 4. Forecast with both and time them.
    let out = merged.execute(&[x.clone()])?;
    println!("merged forecast logits: {:?}", out[0].shape());

    let (t_base, _) = bench(2, 5, || {
        baseline.execute(&[x.clone()]).unwrap();
    });
    let (t_merge, _) = bench(2, 5, || {
        merged.execute(&[x.clone()]).unwrap();
    });
    println!(
        "baseline {:.1} ms/batch | merged {:.1} ms/batch | accel {:.2}x",
        t_base * 1e3,
        t_merge * 1e3,
        t_base / t_merge
    );
    Ok(())
}
