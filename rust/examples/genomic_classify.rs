//! State-space example (paper §5.4): train a Mamba classifier on long
//! genomic sequences, then compare local (k=1) against global (k=t/2)
//! token merging — local merging should be both faster and more accurate.
//!
//!     cargo run --release --offline --features pjrt --example genomic_classify [steps]

use anyhow::Result;
use tomers::data::genomic;
use tomers::eval;
use tomers::runtime::{Engine, WeightStore};
use tomers::tensor::Tensor;
use tomers::train;
use tomers::util::Rng;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let engine = Engine::new("artifacts")?;
    let identity = "mamba_L4";

    // ---- train on planted-motif genomic sequences ---------------------------
    let mut model = engine.load(&format!("{identity}__train"))?;
    let init = WeightStore::load(&std::path::Path::new("artifacts")
        .join(format!("{identity}.weights.bin")))?;
    model.bind_weights(&init)?;
    let batch = model.manifest.batch();
    let m = model.manifest.config_usize("m").unwrap();
    println!("training {identity} on {m}-nucleotide sequences for {steps} steps ...");
    let mut rng = Rng::new(7);
    let report = train::train_loop(
        &mut model,
        &init,
        steps,
        |_| {
            let (ids, labels) = genomic::batch(batch, m, &mut rng);
            (
                Tensor::from_i32(&[batch, m], ids).unwrap(),
                Tensor::from_i32(&[batch], labels).unwrap(),
            )
        },
        |step, loss| {
            if step % 25 == 0 {
                println!("  step {step:>4}  ce {loss:.4}");
            }
            true
        },
    )?;

    // ---- evaluate merge variants --------------------------------------------
    println!("\n{:<16} {:>10} {:>10}", "variant", "accuracy", "ms/batch");
    let mut eval_rng = Rng::new(0xE7A1);
    let mut base_ms = 0.0;
    for tag in ["r0", "r64_k1", "r128_k1", "r64_kglobal", "r128_kglobal"] {
        let mut variant = engine.load(&format!("{identity}__{tag}"))?;
        variant.bind_weights(&report.final_weights)?;
        let (mut correct, mut total, mut secs) = (0.0, 0usize, 0.0);
        for _ in 0..12 {
            let (ids, labels) = genomic::batch(batch, m, &mut eval_rng);
            let x = Tensor::from_i32(&[batch, m], ids)?;
            let t0 = std::time::Instant::now();
            let out = variant.execute(&[x])?;
            secs += t0.elapsed().as_secs_f64();
            correct += eval::accuracy(&out[0], &labels)? * batch as f64;
            total += batch;
        }
        let ms = secs / 12.0 * 1e3;
        if tag == "r0" {
            base_ms = ms;
        }
        println!(
            "{:<16} {:>9.1}% {:>8.1}ms  ({:.2}x)",
            tag,
            100.0 * correct / total as f64,
            ms,
            base_ms / ms
        );
    }
    println!("\nlocal (k=1) merging keeps the linear-complexity inductive bias\nthe paper designs for state-space models (table 3).");
    Ok(())
}
