//! Serving example: the Layer-3 coordinator routing a mixed workload
//! through merge-rate variants chosen by the spectral-entropy policy —
//! the serving-system realisation of the paper's dynamic merging (§5.5).
//!
//!     cargo run --release --offline --features pjrt --example serve_chronos [n_requests]

use std::time::Duration;

use anyhow::Result;
use tomers::coordinator::{self, policy::Variant, ForecastRequest, MergePolicy, ServerConfig};
use tomers::data;
use tomers::util::Rng;

fn main() -> Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(120);

    // Variants from least to most aggressive merging; the policy maps
    // low-entropy (clean) inputs to r=0 and high-entropy (noisy) inputs to
    // r=128 — noisy series tolerate (and often benefit from) merging
    // (paper table 4).
    let policy = MergePolicy::uniform(
        vec![
            Variant::fixed("chronos_s__r0", 0),
            Variant::fixed("chronos_s__r32", 32),
            Variant::fixed("chronos_s__r128", 128),
        ],
        3.0,
        7.5,
    );
    let handle = coordinator::server::serve(ServerConfig {
        artifact_dir: "artifacts".into(),
        policy,
        max_wait: Duration::from_millis(20),
        max_queue: 4096,
        merge_workers: 0,
        merge: coordinator::default_host_merge(),
        streaming: None,
        prefer_manifest_spec: true,
        faults: coordinator::FaultPolicy::default(),
    })?;
    let client = handle.client();

    println!("submitting {n} requests (alternating clean/noisy series) ...");
    let mut rng = Rng::new(2024);
    let pending: Vec<_> = (0..n as u64)
        .map(|id| {
            let profile = if id % 2 == 0 { "weather" } else { "ettm1" };
            let series = data::generate(data::profile(profile).unwrap(), 512, rng.next_u64());
            client.submit(ForecastRequest { id, context: series.column(0) }).unwrap()
        })
        .collect();

    let mut by_variant = std::collections::BTreeMap::new();
    for rx in pending {
        let resp = rx.recv()?;
        *by_variant.entry(resp.variant).or_insert(0usize) += 1;
    }
    println!("routing decisions:");
    for (variant, count) in by_variant {
        println!("  {variant}: {count}");
    }
    println!("{}", client.metrics_report()?);
    handle.shutdown()?;
    Ok(())
}
