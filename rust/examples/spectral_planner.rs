//! The §6.2 analysis as a tool: compute the spectral statistics that
//! predict token-merging benefit (spectral entropy, THD) for every
//! synthetic dataset, and show the merge-policy decisions they drive —
//! all without touching a model (the paper's point: the predictors need
//! no downstream evaluation).
//!
//!     cargo run --release --offline --example spectral_planner

use anyhow::Result;
use tomers::coordinator::{policy::Variant, MergePolicy};
use tomers::data;
use tomers::merging::{similarity_complexity, speedup_bound};
use tomers::signal;

fn main() -> Result<()> {
    println!("dataset predictors (paper table 4):");
    println!("{:<12} {:>10} {:>8}   expected merging outcome", "dataset", "entropy", "THD");
    let policy = MergePolicy::uniform(
        vec![
            Variant::fixed("r0", 0),
            Variant::fixed("r32", 32),
            Variant::fixed("r128", 128),
        ],
        3.0,
        7.5,
    );
    for profile in data::PROFILES {
        let series = data::generate(profile, 4096, 2024);
        let (entropy, thd) = data::dataset_stats(&series, 1024);
        let decision = policy.decide(&series.column(0)[..1024]);
        let outcome = if decision.variant.r() >= 128 {
            "quality gain expected (noisy: merging = adaptive low-pass)"
        } else if decision.variant.r() > 0 {
            "neutral-to-positive"
        } else {
            "merge conservatively (clean signal)"
        };
        println!(
            "{:<12} {:>10.2} {:>8.1}   r={} — {}",
            profile.name, entropy, thd, decision.variant.r(), outcome
        );
    }

    println!("\nlocal-merging complexity (eq. 2), t = 16000 tokens:");
    println!("{:>8} {:>16} {:>10}", "k", "similarity ops", "vs k=1");
    let base = similarity_complexity(16_000, 1);
    for k in [1usize, 8, 64, 512, 8000] {
        let c = similarity_complexity(16_000, k);
        println!("{:>8} {:>16} {:>9.0}x", k, c, c as f64 / base as f64);
    }

    println!("\nmerging speed-up upper bound (appendix B.1):");
    for l in [2u32, 4, 6, 8, 10] {
        println!("  L = {:>2}: <= {:.2}x", l, speedup_bound(l));
    }

    println!("\nGaussian filtering vs merging (fig. 6 intuition):");
    let noisy = data::generate(data::profile("ettm1").unwrap(), 1024, 5);
    let col = noisy.column(0);
    for sigma in [0.0, 1.0, 2.0, 4.0] {
        let f = signal::gaussian_filter(&col, sigma);
        println!(
            "  sigma {:>3}: spectral entropy {:.2}",
            sigma,
            signal::spectral_entropy(&f)
        );
    }
    Ok(())
}
