//! End-to-end driver (DESIGN.md deliverable): train a time series
//! transformer from scratch with the Rust training loop driving the AOT
//! train-step artifact, log the loss curve, then serve the trained model
//! with token merging and report the accuracy/throughput trade-off.
//!
//!     cargo run --release --offline --features pjrt --example train_forecaster [steps]
//!
//! This exercises every layer: L1 similarity kernels (inside the compiled
//! graphs), the L2 model + merging + Adam graph, and the L3 loop,
//! evaluation and selection logic.

use anyhow::Result;
use tomers::bench::forecast_suite::{dataset, eval_forecast};
use tomers::data::Split;
use tomers::eval::{self, OperatingPoint};
use tomers::runtime::{Engine, WeightStore};
use tomers::train;
use tomers::util::Rng;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let engine = Engine::new("artifacts")?;
    let identity = "fc_transformer_L4";
    let ds_name = "etth1";

    // ---- train -------------------------------------------------------------
    let mut model = engine.load(&format!("{identity}__train"))?;
    let init = WeightStore::load(&std::path::Path::new("artifacts")
        .join(format!("{identity}.weights.bin")))?;
    model.bind_weights(&init)?;
    let batch = model.manifest.batch();
    let train_ds = dataset(ds_name, 6000, 192, 96, Split::Train, 2024);
    let mut rng = Rng::new(42);
    println!("training {identity} on synthetic {ds_name} for {steps} steps ...");
    let mut curve = Vec::new();
    let report = train::train_loop(
        &mut model,
        &init,
        steps,
        |_| {
            let idx: Vec<usize> = (0..batch).map(|_| rng.below(train_ds.len())).collect();
            train_ds.batch(&idx)
        },
        |step, loss| {
            if step % 20 == 0 {
                println!("  step {step:>4}  train mse {loss:.4}");
                curve.push((step, loss));
            }
            true
        },
    )?;
    println!(
        "trained {} steps in {:.1}s ({:.0} ms/step)",
        report.steps,
        report.seconds,
        1e3 * report.seconds / report.steps as f64
    );

    // ---- evaluate every merge variant ---------------------------------------
    let test = dataset(ds_name, 6000, 192, 96, Split::Test, 2024);
    let mut points = Vec::new();
    for tag in ["r0", "r16", "r32"] {
        let mut variant = engine.load(&format!("{identity}__{tag}"))?;
        variant.bind_weights(&report.final_weights)?;
        let (mse, thr) = eval_forecast(&variant, &test, 48)?;
        println!("  {tag:<4} test mse {mse:.4}  throughput {thr:.1} windows/s");
        points.push(OperatingPoint { name: tag.into(), mse, throughput: thr });
    }
    let sel = eval::select_fastest_within(&points[0], &points[1..], 0.01);
    println!(
        "paper §5.1 selection: {} -> {:.2}x acceleration at {:+.1}% MSE",
        sel.name,
        sel.accel(&points[0]),
        sel.mse_delta_pct(&points[0]),
    );
    Ok(())
}
