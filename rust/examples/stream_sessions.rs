//! Streaming sessions quickstart: incremental causal merging +
//! session-managed continuous batching, fully offline (no PJRT, no
//! artifacts — the decode stage is a synthetic device).
//!
//!     cargo run --release --offline --example stream_sessions
//!
//! Three things to watch in the output:
//! 1. the incremental state stays bit-for-bit equal to a full causal
//!    recompute while paying O(points) per append,
//! 2. the session manager routes clean vs. noisy streams to different
//!    merge thresholds (paper §6.2: spectral entropy predicts merging
//!    tolerance),
//! 3. decode steps batch ready sessions FIFO-fair at mixed fill levels.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;
use tomers::coordinator::{run_stream_stages, FaultPolicy, Metrics, StreamEvent, VariantMeta};
use tomers::merging::{IncrementalMerge, MergeSpec};
use tomers::streaming::{SessionManager, StreamingConfig};
use tomers::util::{lock_ignore_poison as lock, Rng};

fn main() -> Result<()> {
    // -- 1. the incremental invariant, shown directly --------------------
    let spec = MergeSpec::dynamic(0.6, 1).with_causal();
    let mut inc = IncrementalMerge::new(spec.clone(), 1)?;
    let mut rng = Rng::new(7);
    let mut history: Vec<f32> = Vec::new();
    for _ in 0..64 {
        let pts: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
        history.extend_from_slice(&pts);
        inc.append(&pts); // O(16) — never a function of history length
    }
    let t = history.len();
    let full = spec.compile(t, 1)?.run(&history, &vec![1.0; t]);
    let (mut snap_t, mut snap_s) = (Vec::new(), Vec::new());
    inc.snapshot_into(&mut snap_t, &mut snap_s);
    assert_eq!(snap_t, full.tokens, "incremental == full recompute, bit for bit");
    println!(
        "incremental causal merge: {} raw -> {} merged tokens ({} pairs), \
         identical to the full recompute",
        t,
        inc.len(),
        inc.merged_pairs()
    );

    // -- 2. entropy-routed admission -------------------------------------
    let mut manager = SessionManager::new(StreamingConfig::default())?;
    let now = Instant::now();
    let sine: Vec<f32> = (0..256)
        .map(|i| (2.0 * std::f64::consts::PI * 4.0 * i as f64 / 256.0).sin() as f32)
        .collect();
    let noise: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
    manager.admit(0, &sine, now)?;
    manager.admit(1, &noise, now)?;
    for id in [0u64, 1] {
        let s = manager.session(id).unwrap();
        println!(
            "session {id}: spec {:?}  ({} raw -> {} merged)",
            s.spec().mode,
            s.merge().raw_len(),
            s.merged_len()
        );
    }

    // -- 3. continuous batching through the staged decode pipeline -------
    let (tx, rx) = std::sync::mpsc::channel();
    for round in 0..10 {
        for id in 0..6u64 {
            let pts: Vec<f32> = (0..24)
                .map(|i| {
                    let t = (round * 24 + i) as f64;
                    if id % 2 == 0 {
                        (2.0 * std::f64::consts::PI * t / 48.0).sin() as f32
                    } else {
                        rng.normal() as f32
                    }
                })
                .collect();
            tx.send(StreamEvent::Append { session: id, points: pts }).unwrap();
        }
    }
    drop(tx);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let forecasts = Arc::new(Mutex::new(0u64));
    let sink = Arc::clone(&forecasts);
    run_stream_stages(
        rx,
        VariantMeta { capacity: 4, m: 64 },
        StreamingConfig::default(),
        tomers::runtime::WorkerPool::global(),
        Arc::clone(&metrics),
        FaultPolicy::default(),
        |step| Ok(vec![vec![0.0f32; 8]; step.rows]), // synthetic device
        move |_id, _forecast| *lock(&sink) += 1,
    )?;
    println!("{} rolling forecasts delivered", lock(&forecasts));
    println!("{}", lock(&metrics).report());
    Ok(())
}
