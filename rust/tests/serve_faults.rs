//! Fault-injection suite for the serving stages (ISSUE 6 acceptance,
//! DESIGN.md §10) — PJRT-free, driving the identical machinery `tomers
//! serve` runs with synthetic devices behind a seeded [`FaultPlan`]:
//!
//! * liveness: under 20% injected device faults (errors, latency spikes,
//!   panics) every submitted request reaches exactly one **terminal**
//!   outcome — no hung `submit()` receiver, no silently dropped channel;
//! * accounting: the delivery monitor's ledger balances, per-session
//!   forecast order is preserved across redelivery, and outbox memory
//!   stays within its configured bound;
//! * degradation: a repeatedly-faulting variant crosses its quarantine
//!   budget; a faulted decode step re-enqueues its sessions for a later
//!   step instead of losing them.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use tomers::coordinator::{
    call_with_retry, pipeline, run_serve_stages, run_stream_stages, DeliveryMonitor,
    FaultContext, FaultPlan, FaultPolicy, ForecastOutcome, ForecastRequest, Metrics, PrepJob,
    StreamEvent, VariantMeta,
};
use tomers::merging::MergeSpec;
use tomers::runtime::WorkerPool;
use tomers::streaming::StreamingConfig;
use tomers::util::lock_ignore_poison as lock;

type Responses = Vec<mpsc::Receiver<tomers::coordinator::ForecastResponse>>;

/// Fast-backoff policy so the suite runs in seconds; the semantics are
/// the serving defaults.
fn fast_policy() -> FaultPolicy {
    FaultPolicy {
        backoff_base: Duration::from_micros(100),
        backoff_max: Duration::from_millis(1),
        ..FaultPolicy::default()
    }
}

/// `requests` single-variant jobs batched to `capacity`, with every
/// response receiver kept for the liveness check.
fn make_jobs(
    requests: usize,
    capacity: usize,
    m: usize,
    variant: &str,
) -> (Vec<PrepJob>, Responses) {
    let mut jobs = Vec::new();
    let mut receivers = Vec::with_capacity(requests);
    let mut batch = Vec::new();
    for id in 0..requests as u64 {
        let (rtx, rrx) = mpsc::channel();
        let context: Vec<f32> = (0..m).map(|i| ((id as usize + i) % 5) as f32 * 0.2).collect();
        batch.push((ForecastRequest { id, context }, Instant::now(), rtx));
        receivers.push(rrx);
        if batch.len() == capacity {
            jobs.push(PrepJob { variant: variant.to_string(), batch: std::mem::take(&mut batch) });
        }
    }
    if !batch.is_empty() {
        jobs.push(PrepJob { variant: variant.to_string(), batch });
    }
    (jobs, receivers)
}

fn stream_events(sessions: u64, rounds: usize, frames: usize) -> Vec<StreamEvent> {
    let mut events = Vec::new();
    for round in 0..rounds {
        for s in 0..sessions {
            events.push(StreamEvent::Append {
                session: s,
                points: (0..frames).map(|i| ((round * frames + i) as f32 * 0.1).sin()).collect(),
            });
        }
    }
    events
}

/// THE acceptance pin: >= 200 batch requests and >= 20 stream sessions
/// through the dual serving loop with seeded 20% device faults — every
/// request terminal, per-session forecast order preserved across
/// redelivery, outbox memory within its bound, delivery ledger balanced.
#[test]
fn seeded_faults_leave_every_request_terminal_and_accounted() {
    let (requests, sessions, rounds) = (200usize, 20u64, 6usize);
    let policy = FaultPolicy {
        request_deadline: Some(Duration::from_secs(30)),
        step_deadline: Some(Duration::from_millis(100)),
        outbox_cap: 4,
        ..fast_policy()
    };
    let (capacity, m) = (4usize, 32usize);
    let metas: BTreeMap<String, VariantMeta> =
        [("v".to_string(), VariantMeta { capacity, m })].into();
    let (jobs, receivers) = make_jobs(requests, capacity, m, "v");
    let (jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(jobs.len());
    for job in jobs {
        jobs_tx.send(job).unwrap();
    }
    drop(jobs_tx);
    // the feeder holds the event channel open past the last append so the
    // prep thread can harvest faulted step buffers and requeue their
    // windows before the shutdown flush (a buffer recycled after the
    // channel closes is lost with the pipeline — see spawn_stream_prep)
    let (ev_tx, ev_rx) = mpsc::channel::<StreamEvent>();
    let feeder = std::thread::spawn(move || {
        for ev in stream_events(sessions, rounds, 4) {
            ev_tx.send(ev).unwrap();
        }
        std::thread::sleep(Duration::from_millis(250));
    });

    let scfg = StreamingConfig { max_sessions: sessions as usize, min_new: 4, ..Default::default() };
    let stream_meta = VariantMeta { capacity: 4, m: 16 };
    let delivery =
        Arc::new(Mutex::new(DeliveryMonitor::new(policy.outbox_cap, policy.forecast_ttl)));
    let sink = Arc::clone(&delivery);
    let plan = Arc::new(Mutex::new(FaultPlan::new(7, 0.2)));
    let (bplan, splan) = (Arc::clone(&plan), Arc::clone(&plan));
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    run_serve_stages(
        jobs_rx,
        ev_rx,
        metas,
        pipeline::default_host_merge(),
        2,
        stream_meta,
        scfg,
        WorkerPool::global(),
        Arc::clone(&metrics),
        FaultContext::new(policy.clone()),
        move |ready| {
            FaultPlan::gate(&bplan)?;
            Ok(vec![vec![1.0f32; 8]; ready.rows])
        },
        move |step| {
            FaultPlan::gate(&splan)?;
            Ok(vec![vec![2.0f32; 8]; step.rows])
        },
        move |session, forecast| {
            lock(&sink).offer(session, forecast, Instant::now());
        },
    )
    .expect("the serving loop must survive injected faults");
    feeder.join().expect("feeder");

    // liveness: every batch request answered with one terminal outcome
    let (mut delivered, mut timeouts, mut failed) = (0usize, 0usize, 0usize);
    for rrx in receivers {
        let resp = rrx.recv().expect("no request may hang or be dropped");
        match resp.outcome {
            ForecastOutcome::Delivered => delivered += 1,
            ForecastOutcome::DeadlineExceeded => timeouts += 1,
            ForecastOutcome::Failed(_) => failed += 1,
        }
    }
    assert_eq!(delivered + timeouts + failed, requests);
    assert!(delivered > 0, "a 20% fault rate must not take the service down");

    // at 20% over this many device calls, injections are a statistical
    // certainty; the fault machinery must have both retried and, with
    // retries sometimes exhausted, recorded faults somewhere
    let p = lock(&plan);
    assert!(p.injected() >= 1, "the plan injected nothing — harness wired wrong?");
    drop(p);
    let mx = lock(&metrics);
    let f = mx.faults();
    assert!(
        f.exec_retries + f.step_retries + f.exec_faults + f.step_faults >= 1,
        "faults were injected but nothing recorded: {f:?}"
    );
    drop(mx);

    // delivery accounting: order across redelivery, bounded memory,
    // balanced ledger
    let mut d = lock(&delivery);
    assert!(d.max_outbox_depth() <= d.cap(), "outbox memory bound violated");
    let mut first_seqs: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
    for s in 0..sessions {
        let got = d.collect(s);
        assert!(
            got.windows(2).all(|w| w[0].0 < w[1].0),
            "session {s}: sequence order violated on first collect"
        );
        first_seqs.insert(s, got.iter().map(|(q, _)| *q).collect());
    }
    // nothing acked yet: a second collect redelivers the same forecasts,
    // in the same order
    let mut redelivered_total = 0usize;
    for s in 0..sessions {
        let again: Vec<u64> = d.collect(s).iter().map(|(q, _)| *q).collect();
        assert_eq!(&again, &first_seqs[&s], "session {s}: redelivery changed order");
        redelivered_total += again.len();
    }
    assert_eq!(d.stats().redelivered as usize, redelivered_total);
    // ack even sessions, expire the rest; the ledger must balance exactly
    for s in (0..sessions).step_by(2) {
        if let Some(&last) = first_seqs[&s].last() {
            d.ack(s, last, Instant::now());
        }
    }
    let pending = d.total_pending();
    let expired = d.expire(Instant::now() + policy.forecast_ttl + Duration::from_secs(1));
    assert_eq!(expired, pending, "expiry must settle exactly the unacked remainder");
    assert_eq!(d.total_pending(), 0);
    let st = d.stats();
    assert_eq!(
        st.enqueued,
        st.acked + st.expired_undelivered + st.dropped_overflow,
        "delivery ledger out of balance: {st:?}"
    );
    assert!(st.enqueued > 0, "stream sessions produced no forecasts at all");
}

/// Transient faults are absorbed by retry: a device that fails exactly
/// once per batch still delivers everything, and the retries are
/// counted.
#[test]
fn transient_faults_retry_to_success() {
    let (capacity, m) = (2usize, 16usize);
    let metas: BTreeMap<String, VariantMeta> =
        [("v".to_string(), VariantMeta { capacity, m })].into();
    let (jobs, receivers) = make_jobs(8, capacity, m, "v");
    let n_batches = jobs.len();
    let (jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(n_batches);
    for job in jobs {
        jobs_tx.send(job).unwrap();
    }
    drop(jobs_tx);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let mut calls = 0usize;
    pipeline::run_stages(
        jobs_rx,
        metas,
        MergeSpec::fixed_r(Vec::new(), 4),
        1,
        WorkerPool::global(),
        Arc::clone(&metrics),
        FaultContext::new(fast_policy()),
        move |ready| {
            calls += 1;
            if calls % 2 == 1 {
                anyhow::bail!("transient device fault");
            }
            Ok(vec![vec![0.5f32; 4]; ready.rows])
        },
    )
    .unwrap();
    for rrx in receivers {
        let resp = rrx.recv().expect("terminal response");
        assert!(resp.outcome.is_delivered(), "retry must absorb the transient fault");
    }
    let mx = lock(&metrics);
    assert_eq!(mx.faults().exec_retries as usize, n_batches, "one retry per batch");
    assert_eq!(mx.faults().exec_faults, 0);
}

/// A panicking device closure is a fault like any other: caught, retried,
/// and — when persistent — answered with a terminal failure instead of a
/// dead serving thread.
#[test]
fn panicking_device_is_contained() {
    let (capacity, m) = (2usize, 16usize);
    let metas: BTreeMap<String, VariantMeta> =
        [("v".to_string(), VariantMeta { capacity, m })].into();
    let (jobs, receivers) = make_jobs(4, capacity, m, "v");
    let (jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(jobs.len());
    for job in jobs {
        jobs_tx.send(job).unwrap();
    }
    drop(jobs_tx);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    pipeline::run_stages(
        jobs_rx,
        metas,
        MergeSpec::fixed_r(Vec::new(), 4),
        1,
        WorkerPool::global(),
        Arc::clone(&metrics),
        FaultContext::new(FaultPolicy { max_retries: 1, ..fast_policy() }),
        |_ready| -> anyhow::Result<Vec<Vec<f32>>> { panic!("device blew up") },
    )
    .expect("the loop survives a panicking device");
    for rrx in receivers {
        let resp = rrx.recv().expect("terminal response despite panics");
        match resp.outcome {
            ForecastOutcome::Failed(reason) => {
                assert!(reason.contains("device blew up"), "panic payload preserved: {reason}")
            }
            other => panic!("expected Failed, got {other:?}"),
        }
    }
}

/// Requests already past their deadline get `DeadlineExceeded` without
/// burning device work; the device is never called for a fully-expired
/// batch.
#[test]
fn expired_requests_time_out_without_device_work() {
    let (capacity, m) = (2usize, 16usize);
    let metas: BTreeMap<String, VariantMeta> =
        [("v".to_string(), VariantMeta { capacity, m })].into();
    // enqueue timestamps in the past, far beyond the 5ms deadline
    let mut receivers = Vec::new();
    let mut batch = Vec::new();
    let stale = Instant::now() - Duration::from_millis(250);
    for id in 0..4u64 {
        let (rtx, rrx) = mpsc::channel();
        batch.push((ForecastRequest { id, context: vec![0.1; m] }, stale, rtx));
        receivers.push(rrx);
    }
    let (jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(2);
    jobs_tx.send(PrepJob { variant: "v".into(), batch: batch.drain(..2).collect() }).unwrap();
    jobs_tx.send(PrepJob { variant: "v".into(), batch }).unwrap();
    drop(jobs_tx);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let executed = Arc::new(Mutex::new(0usize));
    let count = Arc::clone(&executed);
    pipeline::run_stages(
        jobs_rx,
        metas,
        MergeSpec::fixed_r(Vec::new(), 4),
        1,
        WorkerPool::global(),
        Arc::clone(&metrics),
        FaultContext::new(FaultPolicy {
            request_deadline: Some(Duration::from_millis(5)),
            ..fast_policy()
        }),
        move |ready| {
            *lock(&count) += 1;
            Ok(vec![vec![0.0f32; 4]; ready.rows])
        },
    )
    .unwrap();
    for rrx in receivers {
        let resp = rrx.recv().expect("terminal timeout response");
        assert_eq!(resp.outcome, ForecastOutcome::DeadlineExceeded);
        assert!(resp.forecast.is_empty());
    }
    assert_eq!(*lock(&executed), 0, "expired batches must skip the device entirely");
    assert_eq!(lock(&metrics).faults().timeouts, 4);
}

/// A variant that faults past its budget is quarantined in the shared
/// tracker — the signal the intake thread's graceful-degradation reroute
/// consumes (`fallback` walks to the next cheaper variant; pinned at the
/// unit level in coordinator::faults).
#[test]
fn persistent_faults_quarantine_the_variant() {
    let (capacity, m) = (2usize, 16usize);
    let metas: BTreeMap<String, VariantMeta> =
        [("v".to_string(), VariantMeta { capacity, m })].into();
    let (jobs, receivers) = make_jobs(8, capacity, m, "v");
    let (jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(jobs.len());
    for job in jobs {
        jobs_tx.send(job).unwrap();
    }
    drop(jobs_tx);
    let faults = FaultContext::new(FaultPolicy {
        max_retries: 0,
        variant_fault_budget: 2,
        ..fast_policy()
    });
    let tracker = Arc::clone(&faults.tracker);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    pipeline::run_stages(
        jobs_rx,
        metas,
        MergeSpec::fixed_r(Vec::new(), 4),
        1,
        WorkerPool::global(),
        Arc::clone(&metrics),
        faults,
        |_ready| -> anyhow::Result<Vec<Vec<f32>>> { anyhow::bail!("device down hard") },
    )
    .unwrap();
    for rrx in receivers {
        assert!(matches!(
            rrx.recv().expect("terminal").outcome,
            ForecastOutcome::Failed(_)
        ));
    }
    assert!(lock(&tracker).is_quarantined("v"), "budget 2 crossed by 4 faulted batches");
    let ordered = vec!["r0".to_string(), "v".to_string()];
    assert_eq!(
        lock(&tracker).fallback(&ordered, "v"),
        Some("r0"),
        "routing downgrades to the cheaper variant"
    );
    assert_eq!(lock(&metrics).faults().exec_faults, 4);
}

/// A faulted decode step loses nothing: its sessions' windows are
/// restored and served by a later step once the device recovers, and the
/// requeue is visible in the stream stats.
#[test]
fn faulted_decode_steps_requeue_sessions() {
    let sessions = 6u64;
    // keep the intake open so the faulted buffers are harvested (and
    // their windows requeued) before the shutdown flush
    let (ev_tx, ev_rx) = mpsc::channel::<StreamEvent>();
    let feeder = std::thread::spawn(move || {
        for ev in stream_events(sessions, 4, 4) {
            ev_tx.send(ev).unwrap();
        }
        std::thread::sleep(Duration::from_millis(200));
    });
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let delivered: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = Arc::clone(&delivered);
    let mut calls = 0usize;
    run_stream_stages(
        ev_rx,
        VariantMeta { capacity: 4, m: 16 },
        StreamingConfig { min_new: 4, ..Default::default() },
        WorkerPool::global(),
        Arc::clone(&metrics),
        FaultPolicy { max_retries: 0, ..fast_policy() },
        move |step| {
            calls += 1;
            if calls <= 2 {
                anyhow::bail!("decode device hiccup");
            }
            Ok(vec![vec![3.0f32; 8]; step.rows])
        },
        move |id, _forecast| lock(&sink).push(id),
    )
    .unwrap();
    feeder.join().expect("feeder");
    let got = lock(&delivered);
    for id in 0..sessions {
        assert!(got.iter().any(|&s| s == id), "session {id} lost by the faulted steps");
    }
    let mx = lock(&metrics);
    assert!(mx.faults().step_faults >= 2, "both hiccups counted");
    let (_, stats) = mx.stream_snapshot().expect("stream stats recorded");
    assert!(stats.requeued_windows >= 1, "requeue must be visible: {stats:?}");
    assert_eq!(stats.quarantined, 0, "transient hiccups must not evict sessions");
}

/// Repeat offenders are evicted: a session whose decode faults every time
/// it reaches the device crosses `session_fault_budget` and is
/// quarantined, while the healthy sessions keep streaming.
#[test]
fn repeat_offender_sessions_are_quarantined() {
    // feed only one session into an always-faulting device: every step it
    // rides in faults, so its consecutive-fault count climbs to the
    // budget.  The feeder keeps the intake open long enough for the
    // fault -> harvest -> requeue cycle to spin to quarantine.
    let (ev_tx, ev_rx) = mpsc::channel::<StreamEvent>();
    let feeder = std::thread::spawn(move || {
        for ev in stream_events(1, 8, 4) {
            ev_tx.send(ev).unwrap();
        }
        std::thread::sleep(Duration::from_millis(300));
    });
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    run_stream_stages(
        ev_rx,
        VariantMeta { capacity: 2, m: 16 },
        StreamingConfig { min_new: 4, ..Default::default() },
        WorkerPool::global(),
        Arc::clone(&metrics),
        FaultPolicy { max_retries: 0, session_fault_budget: 3, ..fast_policy() },
        |step| -> anyhow::Result<Vec<Vec<f32>>> {
            anyhow::bail!("device poisons every step ({} rows)", step.rows)
        },
        |_id, _forecast| panic!("nothing may be delivered"),
    )
    .unwrap();
    feeder.join().expect("feeder");
    let mx = lock(&metrics);
    let (_, stats) = mx.stream_snapshot().expect("stream stats recorded");
    // at least one eviction; appends landing after it can re-admit the
    // session and quarantine it again, so the count is a floor
    assert!(stats.quarantined >= 1, "the offender must be evicted: {stats:?}");
    assert!(mx.faults().step_faults >= 3, "budget 3 takes three faulted steps");
}

/// Shutdown under fault (ISSUE 6 satellite): with every device call
/// failing and the input channels closed, the loop still drains to
/// completion — terminal responses everywhere, `Ok` from the loop, no
/// wedged thread.  Dropped response receivers change nothing.
#[test]
fn total_device_failure_still_winds_down_cleanly() {
    let (capacity, m) = (2usize, 16usize);
    let metas: BTreeMap<String, VariantMeta> =
        [("v".to_string(), VariantMeta { capacity, m })].into();
    let (jobs, receivers) = make_jobs(6, capacity, m, "v");
    let (jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(jobs.len());
    for job in jobs {
        jobs_tx.send(job).unwrap();
    }
    drop(jobs_tx);
    // half the clients walk away before their responses arrive — the
    // send-side must shrug (Err ignored), not wedge or panic
    let keep: Responses = receivers
        .into_iter()
        .enumerate()
        .filter_map(|(i, rrx)| (i % 2 == 0).then_some(rrx))
        .collect();
    let (ev_tx, ev_rx) = mpsc::channel::<StreamEvent>();
    for ev in stream_events(3, 2, 4) {
        ev_tx.send(ev).unwrap();
    }
    drop(ev_tx);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    run_serve_stages(
        jobs_rx,
        ev_rx,
        metas,
        pipeline::default_host_merge(),
        1,
        VariantMeta { capacity: 2, m: 16 },
        StreamingConfig { min_new: 4, ..Default::default() },
        WorkerPool::global(),
        Arc::clone(&metrics),
        FaultContext::new(FaultPolicy { max_retries: 0, ..fast_policy() }),
        |_ready| -> anyhow::Result<Vec<Vec<f32>>> { anyhow::bail!("batch device dead") },
        |_step| -> anyhow::Result<Vec<Vec<f32>>> { anyhow::bail!("stream device dead") },
        |_session, _forecast| panic!("nothing may be delivered"),
    )
    .expect("total device failure must not hang or error the loop");
    for rrx in keep {
        assert!(matches!(
            rrx.recv().expect("surviving clients still get terminal responses").outcome,
            ForecastOutcome::Failed(_)
        ));
    }
    let mx = lock(&metrics);
    assert!(mx.faults().exec_faults >= 3, "every batch faulted");
    assert_eq!(mx.served(), 0);
}

/// Bounded intake (ISSUE 6 satellite): `try_send` into a full queue plus
/// `call_with_retry` surfaces sustained backpressure as a bounded error —
/// it neither blocks forever nor retries forever.
#[test]
fn intake_backpressure_surfaces_boundedly() {
    let (tx, _rx) = mpsc::sync_channel::<u64>(1);
    tx.send(1).unwrap(); // queue now full, and nobody ever drains it
    let policy = FaultPolicy { max_retries: 3, ..fast_policy() };
    let t0 = Instant::now();
    let out = call_with_retry(
        &policy,
        Some(Instant::now() + Duration::from_millis(50)),
        "stream intake",
        || match tx.try_send(2) {
            Ok(()) => Ok(()),
            Err(_) => anyhow::bail!("intake queue full"),
        },
    );
    assert!(out.result.is_err(), "sustained backpressure must surface");
    assert!(out.attempts <= 4, "1 + max_retries bounds the attempts");
    assert!(t0.elapsed() < Duration::from_secs(5), "backpressure must not block");
}
