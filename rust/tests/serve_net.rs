//! Loopback acceptance suite for the sharded network front (ISSUE 8,
//! DESIGN.md §12) — real TCP on 127.0.0.1, ephemeral ports, synthetic
//! per-shard devices behind seeded [`FaultPlan`]s:
//!
//! * liveness over the wire: >= 200 pipelined forecasts and >= 20 stream
//!   sessions against a 2-shard server under 20% injected device faults —
//!   every request answers with exactly one terminal response;
//! * routing: the shard each response reports equals what an independent
//!   client-side [`ShardRouter`] computes, for every id (the ring is a
//!   pure function of the id — golden-pinned in `net::router` and
//!   cross-checked by `scripts/crosscheck_net.py`);
//! * protocol robustness: malformed JSON answers an error frame and the
//!   connection keeps serving; an oversized frame header is rejected and
//!   the connection closed; an abrupt disconnect leaves outboxes
//!   collectable on reconnect until TTL expiry retires them;
//! * backpressure: a full shard intake answers a terminal
//!   `Failed("backpressure: …")` on the wire, never a hang;
//! * shutdown: `NetServerHandle::shutdown` joins every thread it spawned
//!   (acceptor, connections, shard intake + exec) and returns the merged
//!   per-shard report.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use tomers::coordinator::{
    default_host_merge, DecodeStep, FaultPlan, FaultPolicy, ForecastOutcome, MergePolicy,
    ReadyBatch, Variant, VariantMeta,
};
use tomers::json::Json;
use tomers::net::{
    parse_response, serve_net, write_frame, FrameDecoder, NetClient, NetConfig,
    NetServerHandle, Request, Response, ShardRouter, ShardSpec, DEFAULT_MAX_FRAME_BYTES,
};
use tomers::obs::ObsConfig;
use tomers::runtime::WorkerPool;
use tomers::streaming::StreamingConfig;

const M: usize = 32; // context length of the synthetic "v" variant
const HORIZON: usize = 8;

fn spec(max_queue: usize, ttl: Duration) -> ShardSpec {
    ShardSpec {
        policy: MergePolicy::fixed(Variant::fixed("v", 0)),
        metas: BTreeMap::from([("v".to_string(), VariantMeta { capacity: 4, m: M })]),
        merge: default_host_merge(),
        prep_slots: 2,
        stream_meta: VariantMeta { capacity: 4, m: 16 },
        stream_cfg: StreamingConfig { min_new: 4, d: 1, ..Default::default() },
        max_wait: Duration::from_millis(5),
        max_queue,
        faults: FaultPolicy {
            backoff_base: Duration::from_micros(200),
            backoff_max: Duration::from_millis(2),
            request_deadline: Some(Duration::from_secs(30)),
            step_deadline: Some(Duration::from_millis(100)),
            outbox_cap: 4,
            forecast_ttl: ttl,
            ..FaultPolicy::default()
        },
        obs: ObsConfig::default(),
    }
}

/// A 127.0.0.1:0 server with `cmd_serve_net`'s synthetic device shape;
/// `device_sleep` slows the batch device down (backpressure tests).
fn spawn(
    shards: usize,
    fault_rate: f64,
    max_queue: usize,
    ttl: Duration,
    device_sleep: Duration,
) -> NetServerHandle {
    let cfg = NetConfig { shards, ..NetConfig::default() };
    serve_net(
        &cfg,
        &spec(max_queue, ttl),
        WorkerPool::global(),
        |i| {
            let plan = Arc::new(Mutex::new(FaultPlan::new(7 + i as u64, fault_rate)));
            move |ready: &mut ReadyBatch| -> anyhow::Result<Vec<Vec<f32>>> {
                if device_sleep > Duration::ZERO {
                    std::thread::sleep(device_sleep);
                }
                FaultPlan::gate(&plan)?;
                Ok((0..ready.rows)
                    .map(|r| vec![ready.slab[(r + 1) * M - 1]; HORIZON])
                    .collect())
            }
        },
        |i| {
            let plan = Arc::new(Mutex::new(FaultPlan::new(1000 + i as u64, fault_rate)));
            move |step: &mut DecodeStep| -> anyhow::Result<Vec<Vec<f32>>> {
                FaultPlan::gate(&plan)?;
                // stream_meta is (capacity 4, m 16) at d=1 -> 16-wide rows
                Ok((0..step.rows)
                    .map(|r| vec![step.slab[(r + 1) * 16 - 1]; HORIZON])
                    .collect())
            }
        },
    )
    .expect("server must start")
}

fn connect(handle: &NetServerHandle) -> NetClient {
    let mut c = NetClient::connect_retry(
        &handle.addr().to_string(),
        DEFAULT_MAX_FRAME_BYTES,
        20,
    )
    .expect("loopback connect");
    c.set_timeout(Some(Duration::from_secs(10))).unwrap();
    c
}

/// THE acceptance pin: the wire-level mirror of `serve_faults` — 200
/// batch forecasts + 20 stream sessions against 2 shards at 20% injected
/// device faults, all over one pipelined TCP connection.
#[test]
fn loopback_roundtrip_with_faults_leaves_every_request_terminal() {
    let (requests, sessions, rounds) = (200u64, 20u64, 4usize);
    let handle = spawn(2, 0.2, 256, Duration::from_secs(60), Duration::ZERO);
    let router = ShardRouter::new(2).unwrap();
    let mut c = connect(&handle);

    let base = 10_000u64;
    for i in 0..requests {
        let context: Vec<f32> = (0..M).map(|j| ((i as usize + j) % 7) as f32 * 0.1).collect();
        c.send(&Request::Forecast { id: base + i, context }).unwrap();
    }
    let appends = sessions as usize * rounds;
    for round in 0..rounds {
        for s in 0..sessions {
            let points: Vec<f32> =
                (0..4).map(|j| ((round * 4 + j) as f32 * 0.05).sin()).collect();
            c.send(&Request::Append { session: s, points }).unwrap();
        }
    }

    // drain: responses arrive in server order, tallied by type
    let mut per_shard = [0usize; 2];
    let mut terminal = 0usize;
    let mut append_ok = 0usize;
    let mut session_shard: BTreeMap<u64, usize> = BTreeMap::new();
    let mut seen_forecast = 0u64;
    let mut seen_append = 0usize;
    while seen_forecast < requests || seen_append < appends {
        match c.recv().expect("liveness: every pipelined request answers") {
            Response::Forecast { id, outcome, shard, .. } => {
                seen_forecast += 1;
                assert_eq!(shard, router.shard_for(id), "forecast {id} routed off-ring");
                per_shard[shard] += 1;
                match outcome {
                    ForecastOutcome::Delivered
                    | ForecastOutcome::DeadlineExceeded
                    | ForecastOutcome::Failed(_) => terminal += 1,
                }
            }
            Response::Appended { session, shard } => {
                seen_append += 1;
                append_ok += 1;
                assert_eq!(shard, router.shard_for(session), "session {session} off-ring");
                let prev = *session_shard.entry(session).or_insert(shard);
                assert_eq!(prev, shard, "session {session} moved shards");
            }
            Response::Error { context, reason } => {
                assert!(
                    context == "append" && reason.contains("backpressure"),
                    "unexpected error frame: {context}: {reason}"
                );
                seen_append += 1;
            }
            other => panic!("unexpected response while draining: {other:?}"),
        }
    }
    assert_eq!(terminal as u64, requests, "every forecast exactly one terminal outcome");
    assert_eq!(per_shard.iter().sum::<usize>() as u64, requests);
    assert!(per_shard[0] > 0 && per_shard[1] > 0, "both shards served: {per_shard:?}");
    assert!(append_ok > 0, "at least some appends must land");

    // collect + ack every session, then the summed ledger must balance
    std::thread::sleep(Duration::from_millis(200));
    let mut collected = 0usize;
    for s in 0..sessions {
        match c.call(&Request::Collect { session: s }).unwrap() {
            Response::Collected { session, shard, entries } => {
                assert_eq!(session, s);
                assert_eq!(shard, router.shard_for(s));
                assert!(entries.windows(2).all(|w| w[0].0 < w[1].0), "order violated");
                collected += entries.len();
                if let Some(&(last, _)) = entries.last() {
                    match c.call(&Request::Ack { session: s, upto: last }).unwrap() {
                        Response::Acked { session, count, .. } => {
                            assert_eq!(session, s);
                            assert!(count > 0);
                        }
                        other => panic!("expected acked, got {other:?}"),
                    }
                }
            }
            other => panic!("expected collected, got {other:?}"),
        }
    }
    assert!(collected > 0, "stream sessions must produce rolling forecasts");
    match c.call(&Request::Report).unwrap() {
        Response::Report { text, delivery: d } => {
            assert!(text.contains("process: shards=2"), "merged report: {text}");
            assert!(text.contains("shard=0") && text.contains("shard=1"));
            assert_eq!(
                d.enqueued,
                d.acked + d.expired_undelivered + d.dropped_overflow + d.pending,
                "summed ledger must balance: {d:?}"
            );
        }
        other => panic!("expected report, got {other:?}"),
    }
    drop(c);
    let report = handle.shutdown().expect("drain joins every thread");
    assert!(report.contains("process: shards=2"), "{report}");
}

/// The `metrics` request answers the merged structured metrics
/// (DESIGN.md §13) over the wire: one object per shard plus a process
/// total whose counters agree with what the connection actually did, and
/// the payload renders to non-empty Prometheus text.
#[test]
fn metrics_request_exposes_structured_shard_metrics() {
    let handle = spawn(2, 0.0, 256, Duration::from_secs(60), Duration::ZERO);
    let mut c = connect(&handle);
    let n = 40u64;
    for i in 0..n {
        let context: Vec<f32> = (0..M).map(|j| ((i as usize + j) % 7) as f32 * 0.1).collect();
        c.send(&Request::Forecast { id: i, context }).unwrap();
    }
    let mut delivered = 0u64;
    for _ in 0..n {
        match c.recv().unwrap() {
            Response::Forecast { outcome: ForecastOutcome::Delivered, .. } => delivered += 1,
            Response::Forecast { .. } => {}
            other => panic!("expected forecasts only, got {other:?}"),
        }
    }
    assert_eq!(delivered, n, "fault-free run must deliver everything");

    let metrics = match c.call(&Request::Metrics).unwrap() {
        Response::Metrics { metrics } => metrics,
        other => panic!("expected metrics, got {other:?}"),
    };
    let shards = metrics.req("shards").unwrap().as_arr().unwrap();
    assert_eq!(shards.len(), 2, "one metrics object per shard");
    let total = metrics.req("total").unwrap();
    assert_eq!(total.req("served").unwrap().as_usize().unwrap() as u64, n);
    assert_eq!(total.req("rejected").unwrap().as_usize().unwrap(), 0);
    let lat = total.req("latency").unwrap();
    assert_eq!(lat.req("count").unwrap().as_usize().unwrap() as u64, n);
    // per-shard objects carry the per-stage histograms the recorder fed
    let shard0 = &shards[0];
    assert!(matches!(shard0.req("stages"), Ok(Json::Obj(_))), "stages block present");
    let prom = tomers::obs::prometheus_text(&metrics);
    assert!(prom.contains("tomers_served_total"), "{prom}");
    assert!(prom.contains("tomers_latency_seconds"), "{prom}");

    drop(c);
    handle.shutdown().unwrap();
}

/// Malformed JSON inside a well-formed frame answers a parse error and
/// the connection keeps serving; an oversized frame header is rejected
/// (before allocation — pinned in `net::frame`) and closes it.
#[test]
fn malformed_and_oversized_frames() {
    let handle = spawn(1, 0.0, 64, Duration::from_secs(60), Duration::ZERO);

    // raw socket: NetClient (rightly) cannot send garbage
    let mut s = TcpStream::connect(handle.addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut dec = FrameDecoder::new(DEFAULT_MAX_FRAME_BYTES);
    let mut read_one = |s: &mut TcpStream, dec: &mut FrameDecoder| -> Response {
        loop {
            if let Some(p) = dec.next() {
                return parse_response(&p).unwrap();
            }
            let mut buf = [0u8; 4096];
            let n = s.read(&mut buf).unwrap();
            assert!(n > 0, "connection closed while awaiting a response");
            dec.push(&buf[..n]).unwrap();
        }
    };

    write_frame(&mut s, "{\"type\": \"forecast\"", DEFAULT_MAX_FRAME_BYTES).unwrap();
    match read_one(&mut s, &mut dec) {
        Response::Error { context, .. } => assert_eq!(context, "parse"),
        other => panic!("expected a parse error, got {other:?}"),
    }
    // same connection still serves
    write_frame(
        &mut s,
        "{\"type\": \"collect\", \"session\": 1}",
        DEFAULT_MAX_FRAME_BYTES,
    )
    .unwrap();
    match read_one(&mut s, &mut dec) {
        Response::Collected { session: 1, entries, .. } => assert!(entries.is_empty()),
        other => panic!("expected an empty collect, got {other:?}"),
    }

    // framing violation: a header larger than max_frame_bytes — error
    // frame, then close
    s.write_all(&u32::MAX.to_be_bytes()).unwrap();
    match read_one(&mut s, &mut dec) {
        Response::Error { context, reason } => {
            assert_eq!(context, "framing");
            assert!(reason.contains("max_frame_bytes"), "{reason}");
        }
        other => panic!("expected a framing error, got {other:?}"),
    }
    let mut buf = [0u8; 64];
    let mut closed = false;
    for _ in 0..100 {
        match s.read(&mut buf) {
            Ok(0) => {
                closed = true;
                break;
            }
            Ok(_) => {}
            Err(_) => {
                closed = true; // reset counts as closed too
                break;
            }
        }
    }
    assert!(closed, "a framing violation must close the connection");
    handle.shutdown().unwrap();
}

/// An abrupt disconnect must not lose undelivered forecasts: the
/// session's outbox survives for a reconnecting collector, and TTL expiry
/// (not the disconnect) is what finally retires unacked entries.
#[test]
fn disconnect_preserves_outbox_until_ttl() {
    let ttl = Duration::from_millis(300);
    let handle = spawn(1, 0.0, 64, ttl, Duration::ZERO);

    let mut c1 = connect(&handle);
    for round in 0..3 {
        let points: Vec<f32> = (0..4).map(|j| (round * 4 + j) as f32 * 0.1).collect();
        match c1.call(&Request::Append { session: 5, points }).unwrap() {
            Response::Appended { session: 5, .. } => {}
            other => panic!("expected appended, got {other:?}"),
        }
    }
    // let the decode steps land their rolling forecasts, then vanish
    // without collecting
    std::thread::sleep(Duration::from_millis(250));
    drop(c1);

    let mut c2 = connect(&handle);
    let n = match c2.call(&Request::Collect { session: 5 }).unwrap() {
        Response::Collected { entries, .. } => entries.len(),
        other => panic!("expected collected, got {other:?}"),
    };
    assert!(n > 0, "outbox must survive the disconnect");

    // never acked: once past TTL the report's sweep retires them and the
    // ledger still balances
    std::thread::sleep(ttl + Duration::from_millis(150));
    match c2.call(&Request::Report).unwrap() {
        Response::Report { delivery: d, .. } => {
            assert!(d.expired_undelivered > 0, "TTL must retire unacked entries: {d:?}");
            assert_eq!(d.pending, 0, "nothing may linger past TTL: {d:?}");
            assert_eq!(d.enqueued, d.acked + d.expired_undelivered + d.dropped_overflow);
        }
        other => panic!("expected report, got {other:?}"),
    }
    drop(c2);
    handle.shutdown().unwrap();
}

/// A full shard intake answers a terminal `Failed("backpressure: …")` on
/// the wire — fail-fast, never a hang — and fast requests still complete.
#[test]
fn backpressure_is_failfast_and_terminal() {
    let n = 50u64;
    // max_queue 2 + a slow device: the intake fills almost immediately
    let handle = spawn(1, 0.0, 2, Duration::from_secs(60), Duration::from_millis(30));
    let mut c = connect(&handle);
    for i in 0..n {
        let context: Vec<f32> = vec![0.5; M];
        c.send(&Request::Forecast { id: i, context }).unwrap();
    }
    let (mut rejected, mut served) = (0u64, 0u64);
    for _ in 0..n {
        match c.recv().expect("every forecast still answers") {
            Response::Forecast { outcome, .. } => match outcome {
                ForecastOutcome::Failed(reason) if reason.contains("backpressure") => {
                    rejected += 1
                }
                _ => served += 1,
            },
            other => panic!("expected forecasts only, got {other:?}"),
        }
    }
    assert_eq!(rejected + served, n);
    assert!(rejected > 0, "a 2-deep intake under a slow device must shed load");
    assert!(served > 0, "shedding must not starve everything");
    drop(c);
    handle.shutdown().unwrap();
}

/// Connections over `max_conns` get an error frame and are closed, never
/// queued.
#[test]
fn connection_limit_is_enforced() {
    let cfg = NetConfig { shards: 1, max_conns: 1, ..NetConfig::default() };
    let handle = serve_net(
        &cfg,
        &spec(64, Duration::from_secs(60)),
        WorkerPool::global(),
        |_| {
            |ready: &mut ReadyBatch| -> anyhow::Result<Vec<Vec<f32>>> {
                Ok(vec![vec![0.0; HORIZON]; ready.rows])
            }
        },
        |_| {
            |step: &mut DecodeStep| -> anyhow::Result<Vec<Vec<f32>>> {
                Ok(vec![vec![0.0; HORIZON]; step.rows])
            }
        },
    )
    .unwrap();
    let mut c1 = connect(&handle);
    // a completed roundtrip pins c1 as the one live connection
    match c1.call(&Request::Collect { session: 1 }).unwrap() {
        Response::Collected { .. } => {}
        other => panic!("expected collected, got {other:?}"),
    }
    let mut c2 = NetClient::connect(&handle.addr().to_string(), DEFAULT_MAX_FRAME_BYTES).unwrap();
    c2.set_timeout(Some(Duration::from_secs(5))).unwrap();
    match c2.recv().expect("the refusal must arrive as an error frame") {
        Response::Error { context, reason } => {
            assert_eq!(context, "accept");
            assert!(reason.contains("connection limit"), "{reason}");
        }
        other => panic!("expected the limit error, got {other:?}"),
    }
    drop(c2);
    drop(c1);
    handle.shutdown().unwrap();
}
