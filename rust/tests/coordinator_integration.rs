//! Integration tests for the serving coordinator over real artifacts:
//! policy routing + dynamic batching + PJRT execution end to end.
//! Skipped (with a message) when artifacts are missing.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::path::PathBuf;
use std::time::Duration;

use tomers::coordinator::{
    self, policy::Variant, FaultPolicy, ForecastRequest, MergePolicy, ServerConfig,
};
use tomers::data;
use tomers::util::Rng;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("chronos_s__r0.hlo.txt").exists().then_some(dir)
}

fn server(dir: PathBuf) -> coordinator::ServerHandle {
    let variants = vec![
        Variant::fixed("chronos_s__r0", 0),
        Variant::fixed("chronos_s__r128", 128),
    ];
    coordinator::server::serve(ServerConfig {
        artifact_dir: dir,
        policy: MergePolicy::uniform(variants, 3.0, 7.5),
        max_wait: Duration::from_millis(10),
        max_queue: 256,
        merge_workers: 0,
        merge: tomers::coordinator::default_host_merge(),
        streaming: None,
        prefer_manifest_spec: true,
        faults: FaultPolicy::default(),
    })
    .expect("server start")
}

fn context(profile: &str, seed: u64) -> Vec<f32> {
    let prof = data::profile(profile).unwrap();
    data::generate(prof, 512, seed).column(0)
}

#[test]
fn serves_forecasts_end_to_end() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let handle = server(dir);
    let client = handle.client();
    let resp = client
        .forecast(ForecastRequest { id: 1, context: context("etth1", 3) })
        .expect("forecast");
    assert_eq!(resp.id, 1);
    assert_eq!(resp.forecast.len(), 64); // horizon p = 64
    assert!(resp.forecast.iter().all(|v| v.is_finite()));
    assert!(resp.latency > 0.0);
    handle.shutdown().unwrap();
}

#[test]
fn policy_routes_by_entropy() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let handle = server(dir);
    let client = handle.client();
    // low-entropy (clean periodic weather-like) -> r0; noisy ettm1 -> r128
    let clean = client
        .forecast(ForecastRequest { id: 1, context: context("weather", 5) })
        .unwrap();
    let noisy = client
        .forecast(ForecastRequest { id: 2, context: context("ettm1", 5) })
        .unwrap();
    assert_eq!(clean.variant, "chronos_s__r0", "clean routed to {}", clean.variant);
    assert_eq!(noisy.variant, "chronos_s__r128", "noisy routed to {}", noisy.variant);
    handle.shutdown().unwrap();
}

#[test]
fn batches_concurrent_requests() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let handle = server(dir);
    let client = handle.client();
    let mut rng = Rng::new(9);
    // submit a burst; the batcher should group them (artifact batch = 8)
    let receivers: Vec<_> = (0..16)
        .map(|id| {
            client
                .submit(ForecastRequest { id, context: context("ettm1", rng.next_u64()) })
                .unwrap()
        })
        .collect();
    let mut max_batch = 0usize;
    for rx in receivers {
        let resp = rx.recv().expect("response");
        max_batch = max_batch.max(resp.batch_size);
    }
    assert!(max_batch > 1, "burst was never batched (max batch {max_batch})");
    let report = client.metrics_report().unwrap();
    assert!(report.contains("served=16"), "report: {report}");
    handle.shutdown().unwrap();
}

/// Streaming serve over real artifacts: a configured "streaming" block
/// wires sessions into the serving loop (decode steps + rolling
/// forecasts alongside batch traffic), and `Manifest.merge_spec` — when
/// the artifacts carry one — is preferred over the config declaration.
#[test]
fn streaming_serve_decodes_sessions_end_to_end() {
    use tomers::streaming::StreamingConfig;
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let variants = vec![
        Variant::fixed("chronos_s__r0", 0),
        Variant::fixed("chronos_s__r128", 128),
    ];
    let mut handle = coordinator::server::serve(ServerConfig {
        artifact_dir: dir,
        policy: MergePolicy::uniform(variants, 3.0, 7.5),
        max_wait: Duration::from_millis(10),
        max_queue: 256,
        merge_workers: 0,
        merge: tomers::coordinator::default_host_merge(),
        streaming: Some(StreamingConfig {
            min_new: 8,
            variant: Some("chronos_s__r0".into()),
            ..StreamingConfig::default()
        }),
        prefer_manifest_spec: true,
        faults: FaultPolicy::default(),
    })
    .expect("streaming serve start");
    let client = handle.client();
    let stream = handle.stream_client().expect("streaming configured");
    // batch and stream traffic through the same device thread
    let batch_resp = client
        .forecast(ForecastRequest { id: 1, context: context("etth1", 3) })
        .expect("batch forecast");
    assert_eq!(batch_resp.id, 1);
    assert!(batch_resp.outcome.is_delivered());
    let mut rng = Rng::new(41);
    for _ in 0..3 {
        for id in 0..3u64 {
            let pts: Vec<f32> = (0..16).map(|_| (rng.next_u64() % 7) as f32).collect();
            stream.append(id, pts).expect("stream append");
        }
    }
    // rolling forecasts arrive through the delivery monitor: poll
    // collect + ack until a settle window passes with nothing new
    let mut rolling = 0usize;
    let mut sessions_seen = std::collections::BTreeSet::new();
    let mut idle = 0usize;
    while idle < 4 {
        std::thread::sleep(Duration::from_millis(125));
        let mut got = 0usize;
        for id in 0..3u64 {
            let batch = stream.collect(id);
            if let Some(&(last, _)) = batch.last() {
                stream.ack(id, last);
                sessions_seen.insert(id);
            }
            got += batch.len();
        }
        rolling += got;
        idle = if got == 0 { idle + 1 } else { 0 };
    }
    assert!(rolling >= 3, "sessions must get rolling forecasts ({rolling})");
    assert_eq!(sessions_seen.len(), 3, "every session must get at least one forecast");
    let report = client.metrics_report().expect("report");
    assert!(report.contains("streaming:"), "decode steps recorded: {report}");
    handle.shutdown().unwrap();
}

#[test]
fn metrics_report_counts_variants() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let handle = server(dir);
    let client = handle.client();
    for id in 0..4 {
        client
            .forecast(ForecastRequest { id, context: context("weather", id) })
            .unwrap();
    }
    let report = client.metrics_report().unwrap();
    assert!(report.contains("chronos_s__r0"), "report: {report}");
    handle.shutdown().unwrap();
}
