//! Property-based tests of the token-merging invariants, exercised
//! through the typed `MergeSpec` -> `MergePlan` API (offline build:
//! hand-rolled case generation over the seeded `util::Rng` instead of
//! proptest; several hundred random cases per property).

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use tomers::merging::{
    merge_schedule, similarity_complexity, speedup_bound, unmerge, MergeScratch, MergeSpec,
    PipelineResult,
};
use tomers::util::Rng;

fn rand_tokens(rng: &mut Rng, t: usize, d: usize) -> Vec<f32> {
    (0..t * d).map(|_| rng.normal() as f32).collect()
}

fn rand_sizes(rng: &mut Rng, t: usize) -> Vec<f32> {
    (0..t).map(|_| 1.0 + rng.below(4) as f32).collect()
}

/// One plan-driven merge step (the properties' workhorse).
fn merge_once(
    tokens: &[f32],
    sizes: &[f32],
    t: usize,
    d: usize,
    r: usize,
    k: usize,
) -> PipelineResult {
    MergeSpec::single(r, k)
        .compile(t, d)
        .expect("property case compiles")
        .run(tokens, sizes)
}

/// Property: output shape is exactly t-r, sizes sum is conserved, and the
/// size-weighted token sum is conserved (merging is a convex combination).
#[test]
fn prop_mass_conservation() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..300 {
        let t = 6 + rng.below(60);
        let d = 1 + rng.below(16);
        let t2 = (t - t % 2) / 2;
        let r = rng.below(t2) + 1;
        let k = 1 + rng.below(t2);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes = rand_sizes(&mut rng, t);
        let res = merge_once(&tokens, &sizes, t, d, r, k);
        assert_eq!(res.tokens.len(), (t - r) * d, "case {case}");
        let total: f64 = sizes.iter().map(|&s| s as f64).sum();
        let after: f64 = res.sizes.iter().map(|&s| s as f64).sum();
        assert!((total - after).abs() < 1e-3 * total, "case {case}");
        for j in 0..d {
            let before: f64 = (0..t).map(|p| tokens[p * d + j] as f64 * sizes[p] as f64).sum();
            let got: f64 = (0..t - r)
                .map(|s| res.tokens[s * d + j] as f64 * res.sizes[s] as f64)
                .sum();
            assert!(
                (before - got).abs() < 1e-2 * before.abs().max(1.0),
                "case {case} axis {j}: {before} vs {got}"
            );
        }
    }
}

/// Property: slot_map is surjective onto 0..t-r and the kept (odd/B)
/// tokens appear in increasing slot order (order preservation).
#[test]
fn prop_slot_map_structure() {
    let mut rng = Rng::new(0xB0B);
    for _ in 0..300 {
        let t = 6 + rng.below(40);
        let d = 1 + rng.below(8);
        let t2 = (t - t % 2) / 2;
        let r = rng.below(t2) + 1;
        let k = 1 + rng.below(t2);
        let tokens = rand_tokens(&mut rng, t, d);
        let res = merge_once(&tokens, &vec![1.0; t], t, d, r, k);
        let mut seen = vec![false; t - r];
        for &s in &res.slot_map {
            assert!(s < t - r, "slot out of range");
            seen[s] = true;
        }
        assert!(seen.into_iter().all(|x| x), "slot_map not surjective");
        // B tokens (odd positions) are never merged away: strictly increasing
        let mut prev = None;
        for p in (1..t).step_by(2) {
            let s = res.slot_map[p];
            if let Some(q) = prev {
                assert!(s > q, "B-token slots not increasing at {p}");
            }
            prev = Some(s);
        }
    }
}

/// Property: causality for k = 1 — every merge group spans at most two
/// adjacent original positions, so information never moves backward.
/// Exercised through the causal spec (which validation pins to k = 1).
#[test]
fn prop_causal_k1_adjacency() {
    let mut rng = Rng::new(0xCA5);
    for _ in 0..300 {
        let t = 6 + rng.below(50);
        let d = 1 + rng.below(8);
        let t2 = (t - t % 2) / 2;
        let r = rng.below(t2) + 1;
        let tokens = rand_tokens(&mut rng, t, d);
        let res = MergeSpec::single(r, 1)
            .with_causal()
            .compile(t, d)
            .expect("causal plan")
            .run(&tokens, &vec![1.0; t]);
        for s in 0..t - r {
            let members: Vec<usize> =
                (0..t).filter(|&p| res.slot_map[p] == s).collect();
            let span = members.last().unwrap() - members.first().unwrap();
            assert!(span <= 1, "k=1 group spans {span} > 1: {members:?}");
        }
    }
}

/// Property: merging a constant token set reproduces the constant,
/// regardless of r and k (identical tokens merge losslessly).
#[test]
fn prop_constant_tokens_unchanged() {
    let mut rng = Rng::new(0xC0115);
    for _ in 0..100 {
        let t = 8 + rng.below(30);
        let d = 1 + rng.below(8);
        let t2 = (t - t % 2) / 2;
        let r = rng.below(t2) + 1;
        let k = 1 + rng.below(t2);
        let value: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let tokens: Vec<f32> = (0..t).flat_map(|_| value.clone()).collect();
        let res = merge_once(&tokens, &vec![1.0; t], t, d, r, k);
        for s in 0..t - r {
            for j in 0..d {
                assert!((res.tokens[s * d + j] - value[j]).abs() < 1e-5);
            }
        }
    }
}

/// Property: unmerge returns length-t rows, and rows of singleton slots
/// are bit-identical to their input — both through the free gather and
/// the plan result's own `unmerge`.
#[test]
fn prop_unmerge_roundtrip() {
    let mut rng = Rng::new(0xD1CE);
    for _ in 0..200 {
        let t = 6 + rng.below(40);
        let d = 1 + rng.below(8);
        let t2 = (t - t % 2) / 2;
        let r = rng.below(t2) + 1;
        let tokens = rand_tokens(&mut rng, t, d);
        let res = merge_once(&tokens, &vec![1.0; t], t, d, r, 2 + rng.below(8));
        let um = unmerge(&res.tokens, d, &res.slot_map);
        assert_eq!(um, res.unmerge(d));
        assert_eq!(um.len(), t * d);
        for p in 0..t {
            let s = res.slot_map[p];
            if (res.sizes[s] - 1.0).abs() < 1e-6 {
                assert_eq!(&um[p * d..(p + 1) * d], &tokens[p * d..(p + 1) * d]);
            }
        }
    }
}

/// Property: dynamic merging is monotone in threshold — a higher threshold
/// never merges more tokens (effective count never decreases) — over the
/// spec-valid threshold range.
#[test]
fn prop_dynamic_monotone_in_threshold() {
    let mut rng = Rng::new(0xD110);
    for _ in 0..100 {
        let t = 8 + rng.below(40);
        let d = 2 + rng.below(8);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes = vec![1.0; t];
        let mut prev_eff = 0usize;
        for th in [0.0, 0.3, 0.5, 0.9, 1.1] {
            let res = MergeSpec::dynamic(th, 1)
                .compile(t, d)
                .expect("dynamic plan")
                .run(&tokens, &sizes);
            let eff = *res.token_counts.last().unwrap();
            assert_eq!(eff, res.sizes.len());
            assert!(eff >= prev_eff, "threshold {th}: eff {eff} < {prev_eff}");
            prev_eff = eff;
        }
    }
}

/// Property: eq. 2 complexity is exact at the extremes and monotone in k
/// (both as the free formula and through `MergeSpec::similarity_cost`);
/// the B.1 bound is monotone in depth.
#[test]
fn prop_complexity_and_bound() {
    let mut rng = Rng::new(0xE42);
    for _ in 0..200 {
        let t = 2 * (2 + rng.below(512));
        let t2 = t / 2;
        assert_eq!(similarity_complexity(t, 1), t2);
        assert_eq!(similarity_complexity(t, t2), t2 * t2);
        let k1 = 1 + rng.below(t2);
        let k2 = (k1 + 1 + rng.below(t2)).min(t2);
        assert!(similarity_complexity(t, k1) <= similarity_complexity(t, k2));
        assert_eq!(MergeSpec::single(1, k1).similarity_cost(t), similarity_complexity(t, k1));
    }
    for l in 1..14u32 {
        assert!(speedup_bound(l + 1) > speedup_bound(l));
        assert!(speedup_bound(l) <= 3.0 * l as f64 / 4.0 + 1.0);
    }
}

/// Property: matching respects the band for arbitrary k and returns
/// cosine values in [-1, 1] (through the zero-allocation kernel surface).
#[test]
fn prop_match_band() {
    let mut rng = Rng::new(0xF00D);
    let mut scratch = MergeScratch::new();
    for _ in 0..200 {
        let t = 6 + rng.below(60);
        let d = 1 + rng.below(8);
        let t2 = (t - t % 2) / 2;
        let k = 1 + rng.below(t2);
        let tokens = rand_tokens(&mut rng, t, d);
        tomers::merging::match_tokens_scratch(&tokens, t, d, k, &mut scratch);
        for (i, (&s, &j)) in scratch.scores().iter().zip(scratch.best()).enumerate() {
            assert!((i as isize - j as isize).unsigned_abs() < k);
            assert!((-1.01..=1.01).contains(&s), "cosine out of range: {s}");
        }
    }
}

/// Property: the schedule never drops below q (unless it started there),
/// never merges more than half the even tokens per layer, and is monotone
/// non-increasing — and the spec built from it always compiles.
#[test]
fn prop_schedule_bounds() {
    let mut rng = Rng::new(0x5CED);
    for _ in 0..300 {
        let t = 4 + rng.below(1000);
        let r = rng.below(600);
        let q = 2 + rng.below(16);
        let layers = 1 + rng.below(10);
        let s = merge_schedule(t, r, layers, q);
        assert_eq!(s.len(), layers + 1);
        assert_eq!(s[0], t);
        for w in s.windows(2) {
            assert!(w[1] <= w[0]);
            assert!(w[0] - w[1] <= r);
            assert!(w[1] >= q.min(w[0]));
            assert!(w[0] - w[1] <= (w[0] - w[0] % 2) / 2);
        }
        let spec = MergeSpec::layered_for(t, r, layers, q, 4);
        let plan = spec.compile(t, 1).expect("layered spec compiles");
        assert_eq!(*plan.layer_counts().last().unwrap(), *s.last().unwrap());
    }
}
