//! Dispatch-edge differential tests (ISSUE 7): the explicit-SIMD kernel
//! must be **bitwise** equal to the forced-scalar kernel for
//! [`Accum::F64`], and within the documented 1e-5 score contract for
//! [`Accum::F32`], across dimension sweeps that hit every
//! remainder/alignment edge of both lane widths (4 for f64 and the
//! scalar/NEON f32 paths, 8 for the AVX2 f32 path).  Also pins the
//! cache-blocked matching walk bitwise against the streaming walk at
//! tile boundaries.
//!
//! On a host where [`simd::active_isa`] is already [`Isa::Scalar`], the
//! SIMD-vs-scalar comparisons degenerate to scalar-vs-scalar; the suite
//! prints a WARN so a green run on such a host is not mistaken for
//! vector coverage.
//!
//! NOTE: `simd::force_scalar` is a process-global toggle and tests run
//! concurrently, so tests here never assume the *dispatched* path while
//! the toggle is on; every comparison computes its scalar side through
//! the explicitly-parameterized `Isa::Scalar` primitives or under the
//! toggle with the SIMD side captured first.

use tomers::merging::kernel::{
    match_tokens_scratch_tiled, matching_tile, merge_fixed_r_scratch_accum, pair_score, token_norm,
    Accum,
};
use tomers::merging::simd::{self, Isa};
use tomers::merging::{MergeResult, MergeScratch};
use tomers::util::Rng;

/// d sweep from the ISSUE: 1, 3, lane−1, lane, lane+1, 64, 257 for both
/// the 4-wide and 8-wide lane counts.
const DIMS: [usize; 9] = [1, 3, 4, 5, 7, 8, 9, 64, 257];

fn rand_tokens(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn primitives_simd_equals_scalar_over_dim_sweep() {
    let isa = simd::active_isa();
    if isa == Isa::Scalar {
        eprintln!("WARN: scalar-only host — SIMD differential is vacuous here");
    }
    let mut rng = Rng::new(71);
    for d in DIMS {
        for _ in 0..16 {
            let a = rand_tokens(&mut rng, d);
            let b = rand_tokens(&mut rng, d);
            // F64: exact bit equality across the dispatch boundary
            assert_eq!(
                simd::dot_f64(isa, &a, &b).to_bits(),
                simd::dot_f64(Isa::Scalar, &a, &b).to_bits(),
                "dot_f64 d={d} isa={}",
                isa.name()
            );
            assert_eq!(
                simd::sumsq_f64(isa, &a).to_bits(),
                simd::sumsq_f64(Isa::Scalar, &a).to_bits(),
                "sumsq_f64 d={d} isa={}",
                isa.name()
            );
            // F32 raw reductions: reassociation error scales with the sum
            // of |terms| (the 1e-5 contract is on *normalized* scores, not
            // raw dots), so the tolerance is relative to that magnitude.
            let dot_scale: f64 =
                a.iter().zip(&b).map(|(&x, &y)| (x * y).abs() as f64).sum::<f64>().max(1.0);
            let (dv, ds) = (simd::dot_f32(isa, &a, &b), simd::dot_f32(Isa::Scalar, &a, &b));
            assert!((dv - ds).abs() <= 1e-4 * dot_scale, "dot_f32 d={d}: {dv} vs {ds}");
            let ss_scale = simd::sumsq_f64(Isa::Scalar, &a).max(1.0);
            let (sv, ss) = (simd::sumsq_f32(isa, &a), simd::sumsq_f32(Isa::Scalar, &a));
            assert!((sv - ss).abs() <= 1e-4 * ss_scale, "sumsq_f32 d={d}: {sv} vs {ss}");
        }
    }
}

/// Full-kernel differential: the merged tokens, sizes, slot map and raw
/// match scores under the dispatched ISA are bitwise identical to the
/// forced-scalar run for `Accum::F64`.
#[test]
fn kernel_f64_simd_is_bitwise_equal_to_forced_scalar() {
    let mut rng = Rng::new(72);
    let mut scr_v = MergeScratch::new();
    let mut scr_s = MergeScratch::new();
    let mut out_v = MergeResult::default();
    let mut out_s = MergeResult::default();
    for d in DIMS {
        let (t, k) = (48usize, 5usize);
        let r = 12usize;
        let tokens = rand_tokens(&mut rng, t * d);
        let sizes: Vec<f32> = (0..t).map(|_| 1.0 + rng.below(3) as f32).collect();

        merge_fixed_r_scratch_accum(&tokens, &sizes, t, d, r, k, &mut scr_v, &mut out_v, Accum::F64);
        simd::force_scalar(true);
        merge_fixed_r_scratch_accum(&tokens, &sizes, t, d, r, k, &mut scr_s, &mut out_s, Accum::F64);
        simd::force_scalar(false);

        assert_eq!(bits(scr_v.scores()), bits(scr_s.scores()), "scores d={d}");
        assert_eq!(scr_v.best(), scr_s.best(), "best d={d}");
        assert_eq!(out_v.slot_map, out_s.slot_map, "slot_map d={d}");
        // f32 outputs: exact equality is bit equality for finite values
        // produced by identical op sequences
        assert_eq!(out_v.tokens, out_s.tokens, "tokens d={d}");
        assert_eq!(out_v.sizes, out_s.sizes, "sizes d={d}");
    }
}

/// `Accum::F32` under the dispatched ISA stays within 1e-5 of the
/// forced-scalar F32 scores (the AVX2 path reassociates to 8 lanes with
/// FMA; scalar and NEON are bitwise).
#[test]
fn kernel_f32_simd_tracks_forced_scalar_within_contract() {
    let mut rng = Rng::new(73);
    let mut scr_v = MergeScratch::new();
    let mut scr_s = MergeScratch::new();
    for d in DIMS {
        let (t, k) = (48usize, 5usize);
        let tokens = rand_tokens(&mut rng, t * d);
        match_tokens_scratch_tiled(&tokens, t, d, k, &mut scr_v, Accum::F32, matching_tile(d));
        simd::force_scalar(true);
        match_tokens_scratch_tiled(&tokens, t, d, k, &mut scr_s, Accum::F32, matching_tile(d));
        simd::force_scalar(false);
        for (i, (a, b)) in scr_v.scores().iter().zip(scr_s.scores()).enumerate() {
            assert!((a - b).abs() <= 1e-5, "score[{i}] d={d}: {a} vs {b}");
        }
    }
}

/// The incremental streaming primitives (`token_norm` / `pair_score`) go
/// through the same dispatch — pin them bitwise against the explicit
/// scalar primitives for F64 so the incremental ≡ recompute guarantee
/// cannot split across ISAs.
#[test]
fn streaming_primitives_match_scalar_bitwise() {
    let mut rng = Rng::new(74);
    for d in DIMS {
        let a = rand_tokens(&mut rng, d);
        let b = rand_tokens(&mut rng, d);
        let na = token_norm(&a, Accum::F64);
        let nb = token_norm(&b, Accum::F64);
        assert_eq!(
            na.to_bits(),
            simd::sumsq_f64(Isa::Scalar, &a).sqrt().to_bits(),
            "token_norm d={d}"
        );
        let s = pair_score(&a, &b, na, nb, Accum::F64);
        let scalar = simd::dot_f64(Isa::Scalar, &a, &b) / (na * nb + 1e-8);
        assert_eq!(s.to_bits(), scalar.to_bits(), "pair_score d={d}");
    }
}

/// Tile boundaries: every tile size — including ones that split the band
/// mid-overlap and the degenerate single-token tile — must reproduce the
/// streaming walk bit-for-bit, across dims and band widths.
#[test]
fn blocked_walk_is_bitwise_equal_to_streaming_walk() {
    let mut rng = Rng::new(75);
    let mut blocked = MergeScratch::new();
    let mut streaming = MergeScratch::new();
    for &(t, d, k) in &[
        (130usize, 7usize, 9usize),
        (127, 64, 16),
        (64, 257, 4),
        (33, 1, 40),
        (8, 3, 1),
    ] {
        let tokens = rand_tokens(&mut rng, t * d);
        match_tokens_scratch_tiled(&tokens, t, d, k, &mut streaming, Accum::F64, usize::MAX);
        for tile in [1usize, 2, 5, 16, 63, 64, 65, 4096] {
            match_tokens_scratch_tiled(&tokens, t, d, k, &mut blocked, Accum::F64, tile);
            assert_eq!(
                bits(blocked.scores()),
                bits(streaming.scores()),
                "t={t} d={d} k={k} tile={tile}"
            );
            assert_eq!(blocked.best(), streaming.best(), "t={t} d={d} k={k} tile={tile}");
        }
    }
}
