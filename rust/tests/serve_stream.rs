//! The `tomers serve` streaming wiring, pinned without PJRT (ISSUE 5
//! acceptance): the dual serving loop (`coordinator::serve_loop`) drives
//! batch forecasts **and** stream decode steps through one device thread
//! with shared metrics; the stream-artifact resolver turns a configured
//! `"streaming"` block with no capable artifact into a startup error
//! (the old warn-and-ignore path is gone); and the serving loader
//! prefers `Manifest.merge_spec` over the config's variant declaration
//! by default, with the `"spec_source": "config"` escape hatch.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use tomers::coordinator::{
    default_host_merge, policy::Variant, resolve_stream_artifact, run_serve_stages,
    FaultContext, ForecastRequest, MergePolicy, Metrics, PrepJob, StreamEvent, VariantMeta,
};
use tomers::merging::{MergeMode, MergeSpec};
use tomers::runtime::{Manifest, WorkerPool};
use tomers::streaming::{StreamingConfig, StreamPolicy};
use tomers::util::{lock_ignore_poison as lock, Rng};

fn stream_cfg(d: usize) -> StreamingConfig {
    StreamingConfig {
        max_sessions: 16,
        session_ttl: Duration::from_secs(3600),
        reprobe_every: 10_000,
        raw_window: 64,
        max_merged: 256,
        min_new: 4,
        d,
        policy: StreamPolicy::default(),
        variant: None,
    }
}

/// The acceptance pin: one serving loop, batch jobs and stream sessions
/// in flight together, decode steps counted in the same metrics the
/// batch pipeline records into — no WARN path, actual decode work.
#[test]
fn dual_serving_loop_drives_batch_and_stream_together() {
    let (capacity, m) = (2usize, 16usize);
    let metas: BTreeMap<String, VariantMeta> =
        [("v".to_string(), VariantMeta { capacity, m })].into();

    // batch side: 4 single-request jobs at the artifact's exact length
    let (jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(8);
    let mut responses = Vec::new();
    for id in 0..4u64 {
        let (rtx, rrx) = mpsc::channel();
        let req = ForecastRequest { id, context: vec![0.25; m] };
        jobs_tx
            .send(PrepJob { variant: "v".into(), batch: vec![(req, Instant::now(), rtx)] })
            .unwrap();
        responses.push(rrx);
    }
    drop(jobs_tx);

    // stream side: 5 sessions, several rounds of appends
    let (ev_tx, ev_rx) = mpsc::channel::<StreamEvent>();
    let mut rng = Rng::new(71);
    for _round in 0..3 {
        for id in 0..5u64 {
            let pts: Vec<f32> = (0..6).map(|_| rng.normal() as f32).collect();
            ev_tx.send(StreamEvent::Append { session: id, points: pts }).unwrap();
        }
    }
    drop(ev_tx);

    let stream_meta = VariantMeta { capacity: 2, m: 8 };
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let delivered: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = Arc::clone(&delivered);
    run_serve_stages(
        jobs_rx,
        ev_rx,
        metas,
        default_host_merge(),
        2,
        stream_meta,
        stream_cfg(1),
        WorkerPool::global(),
        Arc::clone(&metrics),
        FaultContext::default(),
        |ready| {
            assert_eq!(ready.variant, "v");
            assert_eq!(ready.slab.len(), capacity * m);
            Ok(vec![vec![1.0f32; 4]; ready.rows])
        },
        |step| {
            assert_eq!(step.slab.len(), 2 * 8);
            assert_eq!(step.sizes.len(), 2 * 8);
            Ok(vec![vec![2.0f32; 3]; step.rows])
        },
        move |id, forecast| {
            assert_eq!(forecast.len(), 3);
            lock(&sink).push(id);
        },
    )
    .unwrap();

    // every batch request answered through the shared loop
    for (id, rrx) in responses.into_iter().enumerate() {
        let resp = rrx.recv().expect("batch response");
        assert_eq!(resp.id, id as u64);
        assert_eq!(resp.variant, "v");
        assert_eq!(resp.forecast, vec![1.0f32; 4]);
        assert!(resp.outcome.is_delivered());
    }
    // every stream session decoded at least once before shutdown flush
    let got = lock(&delivered);
    for id in 0..5u64 {
        assert!(got.iter().any(|&s| s == id), "session {id} never decoded");
    }
    // one metrics object saw both pipelines
    let mx = lock(&metrics);
    assert_eq!(mx.served(), 4, "batch responses recorded");
    assert!(mx.decode_steps() >= 3, "5 sessions / capacity 2 needs >= 3 steps");
    assert_eq!(mx.decode_rows(), got.len());
    let report = mx.report();
    assert!(report.contains("v: 4"), "batch section: {report}");
    assert!(report.contains("streaming:"), "streaming section: {report}");
}

const BASE_MANIFEST: &str = r#"{
  "name": "chronos_s__r0", "family": "chronos",
  "config": {"m": 16},
  "params": [],
  "inputs": [{"name": "x", "shape": [2, 16], "dtype": "f32"}],
  "outputs": [{"name": "out0", "shape": [2, 8], "dtype": "f32"}],
  "meta": {"batch": 2}
}"#;

fn manifests(texts: &[(&str, &str)]) -> Vec<(String, Manifest)> {
    texts
        .iter()
        .map(|(name, text)| (name.to_string(), Manifest::parse(text).unwrap()))
        .collect()
}

fn as_refs(owned: &[(String, Manifest)]) -> BTreeMap<String, &Manifest> {
    owned.iter().map(|(n, m)| (n.clone(), m)).collect()
}

/// The startup gate that replaced the dead WARN: a configured streaming
/// block resolves a capable artifact or errs — never a silent no-op.
#[test]
fn stream_artifact_resolution_gates_startup() {
    let policy = MergePolicy::uniform(
        vec![Variant::fixed("chronos_s__r0", 0), Variant::fixed("chronos_s__r128", 128)],
        3.0,
        7.5,
    );
    let owned = manifests(&[("chronos_s__r0", BASE_MANIFEST)]);
    let loaded = as_refs(&owned);

    // default: the policy's first variant, values-only artifact
    let art = resolve_stream_artifact(&loaded, &policy, &stream_cfg(1)).unwrap();
    assert_eq!(art.variant, "chronos_s__r0");
    assert_eq!(art.meta, VariantMeta { capacity: 2, m: 16 });
    assert!(!art.size_aware);

    // a named variant that is not loaded is a startup error naming the fix
    let cfg = StreamingConfig { variant: Some("chronos_s__r999".into()), ..stream_cfg(1) };
    let err = resolve_stream_artifact(&loaded, &policy, &cfg).unwrap_err();
    assert!(err.to_string().contains("streaming-capable"), "{err}");
    assert!(err.to_string().contains("chronos_s__r999"), "{err}");

    // multivariate: a (2, 8, 3) slab at d = 3 is m = 8; at d = 5 it errs
    let mv = BASE_MANIFEST.replace("[2, 16]", "[2, 8, 3]");
    let owned = manifests(&[("chronos_s__r0", &mv)]);
    let art = resolve_stream_artifact(&as_refs(&owned), &policy, &stream_cfg(3)).unwrap();
    assert_eq!(art.meta, VariantMeta { capacity: 2, m: 8 });
    let err = resolve_stream_artifact(&as_refs(&owned), &policy, &stream_cfg(5)).unwrap_err();
    assert!(err.to_string().contains("channels"), "{err}");

    // a size-aware artifact: second (batch, m) input consumes the size row
    let sa = BASE_MANIFEST.replace(
        r#"[{"name": "x", "shape": [2, 16], "dtype": "f32"}]"#,
        r#"[{"name": "x", "shape": [2, 16], "dtype": "f32"},
            {"name": "sizes", "shape": [2, 16], "dtype": "f32"}]"#,
    );
    let owned = manifests(&[("chronos_s__r0", &sa)]);
    let art = resolve_stream_artifact(&as_refs(&owned), &policy, &stream_cfg(1)).unwrap();
    assert!(art.size_aware);
    // ... but a second input of the wrong shape is not
    let bad = sa.replace(r#""sizes", "shape": [2, 16]"#, r#""sizes", "shape": [2, 4]"#);
    let owned = manifests(&[("chronos_s__r0", &bad)]);
    assert!(resolve_stream_artifact(&as_refs(&owned), &policy, &stream_cfg(1)).is_err());
}

/// The serving loader's spec preference, driven end to end through the
/// real manifest parser: `Manifest.merge_spec` wins by default, the
/// `"spec_source": "config"` escape hatch keeps the declaration.
#[test]
fn manifest_merge_spec_preferred_over_config_declaration() {
    // the artifact says causal dynamic; the config declared fixed r=128
    let with_spec = BASE_MANIFEST.replacen(
        "\"meta\":",
        "\"merge_spec\": {\"mode\": \"dynamic\", \"k\": 1, \"threshold\": 0.9, \
         \"causal\": true}, \"meta\":",
        1,
    );
    let manifest = Manifest::parse(&with_spec).unwrap();
    let manifest_spec = manifest.merge_spec.clone().expect("manifest carries a spec");
    let specs: BTreeMap<String, MergeSpec> =
        [("chronos_s__r128".to_string(), manifest_spec)].into();
    let variants =
        vec![Variant::fixed("chronos_s__r0", 0), Variant::fixed("chronos_s__r128", 128)];

    // default ("spec_source": "manifest"): the artifact is ground truth
    let mut policy = MergePolicy::uniform(variants.clone(), 3.0, 7.5);
    let resolutions = policy.prefer_manifest_specs(&specs, true);
    assert_eq!(resolutions.len(), 1);
    assert!(resolutions[0].disagreed());
    assert!(
        matches!(policy.variants[1].spec.mode, MergeMode::Dynamic { .. }),
        "the policy must route with the manifest's spec"
    );
    assert!(policy.variants[1].spec.causal);
    let line = format!("{}", resolutions[0]);
    assert!(line.contains("manifest merge_spec wins"), "{line}");
    assert!(line.contains("chronos_s__r128"), "{line}");

    // forced config: the declaration survives, the log line says why
    let mut policy = MergePolicy::uniform(variants, 3.0, 7.5);
    let resolutions = policy.prefer_manifest_specs(&specs, false);
    assert_eq!(policy.variants[1].spec.total_r(), 128);
    let line = format!("{}", resolutions[0]);
    assert!(line.contains("config declaration wins"), "{line}");
    assert!(line.contains("spec_source"), "{line}");
}
