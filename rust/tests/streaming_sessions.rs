//! Integration tests for the streaming subsystem: bounded memory under
//! session churn (ISSUE 4 acceptance), TTL/LRU eviction behaviour, and
//! the decode scheduler driving the staged pipeline end to end.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use tomers::coordinator::{run_stream_stages, FaultPolicy, Metrics, StreamEvent, VariantMeta};
use tomers::streaming::{SessionManager, StreamPolicy, StreamingConfig};
use tomers::util::{lock_ignore_poison as lock, Rng};

fn small_cfg(max_sessions: usize, raw_window: usize, max_merged: usize) -> StreamingConfig {
    StreamingConfig {
        max_sessions,
        session_ttl: Duration::from_secs(3600),
        reprobe_every: 10_000,
        raw_window,
        max_merged,
        min_new: 4,
        policy: StreamPolicy::default(),
        ..StreamingConfig::default()
    }
}

/// Acceptance: under 2x-capacity churn the table never exceeds its
/// capacity and per-session state never exceeds its ring/merged bounds,
/// so total memory is bounded by
/// `max_sessions * (raw_window + max_merged)` floats regardless of how
/// many sessions or points ever arrived.
#[test]
fn eviction_bounds_memory_under_2x_churn() {
    let (cap, raw_window, max_merged) = (16usize, 64usize, 96usize);
    let mut m = SessionManager::new(small_cfg(cap, raw_window, max_merged)).unwrap();
    let now = Instant::now();
    let mut rng = Rng::new(23);
    let churn = 2 * cap;
    for id in 0..churn as u64 {
        // long-lived appends: each session sees far more points than its
        // retention bounds
        for _ in 0..6 {
            let pts: Vec<f32> = (0..48).map(|_| rng.normal() as f32).collect();
            m.append(id, &pts, now).unwrap();
        }
        assert!(m.len() <= cap, "table exceeded capacity at id {id}");
        // every retained session respects its per-session bounds
        for sid in 0..=id {
            if let Some(s) = m.session(sid) {
                assert!(s.merged_len() <= max_merged, "session {sid} merged overflow");
                assert!(s.merge().raw_len() >= s.merged_len());
            }
        }
    }
    let stats = m.stats();
    assert_eq!(stats.admitted, churn as u64);
    assert_eq!(stats.evicted_capacity, cap as u64, "exactly the overflow was evicted");
    assert_eq!(m.len(), cap);
    // the survivors are the most recently admitted half
    for id in cap as u64..churn as u64 {
        assert!(m.session(id).is_some(), "recent session {id} missing");
    }
    // a hard upper bound on retained float state
    let bound = cap * (raw_window + max_merged);
    let held: usize = (0..churn as u64)
        .filter_map(|id| m.session(id))
        .map(|s| s.merged_len() + raw_window)
        .sum();
    assert!(held <= bound, "retained state {held} floats exceeds bound {bound}");
}

#[test]
fn ttl_and_lru_interact_sanely() {
    let mut m = SessionManager::new(StreamingConfig {
        session_ttl: Duration::from_millis(50),
        ..small_cfg(4, 32, 64)
    })
    .unwrap();
    let t0 = Instant::now();
    let mut rng = Rng::new(29);
    for id in 0..4u64 {
        let pts: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        m.admit(id, &pts, t0).unwrap();
    }
    // keep 0 and 1 fresh; 2 and 3 go stale
    let later = t0 + Duration::from_millis(100);
    m.append(0, &[1.0], later).unwrap();
    m.append(1, &[1.0], later).unwrap();
    assert_eq!(m.evict_expired(later), 2);
    assert!(m.session(0).is_some() && m.session(1).is_some());
    assert!(m.session(2).is_none() && m.session(3).is_none());
    assert_eq!(m.stats().evicted_ttl, 2);
    // admission on a full-but-fresh table still evicts LRU, never panics
    for id in 10..13u64 {
        let pts: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        m.admit(id, &pts, later).unwrap();
    }
    assert_eq!(m.len(), 4);
}

/// The scheduler + staged pipeline under realistic churn: many sessions
/// at mixed fill levels, partial batches, metrics accounting.
#[test]
fn continuous_batching_serves_mixed_fill_levels() {
    let (tx, rx) = std::sync::mpsc::channel();
    let mut rng = Rng::new(31);
    let sessions = 9u64;
    let mut sent_points = 0usize;
    for round in 0..6 {
        for id in 0..sessions {
            // uneven feed: session id gets id-dependent chunk sizes, so
            // fill levels differ when batches form
            let n = 2 + ((id as usize + round) % 5);
            sent_points += n;
            let pts: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            tx.send(StreamEvent::Append { session: id, points: pts }).unwrap();
        }
    }
    drop(tx);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let delivered: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = Arc::clone(&delivered);
    let meta = VariantMeta { capacity: 4, m: 32 };
    run_stream_stages(
        rx,
        meta,
        small_cfg(16, 64, 64),
        tomers::runtime::WorkerPool::global(),
        Arc::clone(&metrics),
        FaultPolicy::default(),
        |step| {
            // slab invariants hold on every step
            assert!(step.rows >= 1 && step.rows <= 4);
            assert_eq!(step.slab.len(), 4 * 32);
            assert_eq!(step.sizes.len(), 4 * 32);
            assert_eq!(step.sessions.len(), step.rows);
            for r in 0..step.rows {
                let fill = step.fills[r];
                assert!(fill >= 1 && fill <= 32);
                let sizes = &step.sizes[r * 32..(r + 1) * 32];
                assert!(sizes[32 - fill..].iter().all(|&s| s > 0.0), "real tokens sized");
                assert!(sizes[..32 - fill].iter().all(|&s| s == 0.0), "padding size 0");
            }
            for p in step.rows..4 {
                assert!(step.sizes[p * 32..(p + 1) * 32].iter().all(|&s| s == 0.0));
            }
            Ok(vec![vec![1.0f32; 8]; step.rows])
        },
        move |id, f| {
            assert_eq!(f.len(), 8);
            lock(&sink).push(id);
        },
    )
    .unwrap();
    let got = lock(&delivered);
    // every session got at least one rolling forecast
    for id in 0..sessions {
        assert!(got.iter().any(|&s| s == id), "session {id} starved");
    }
    let mx = lock(&metrics);
    assert_eq!(mx.decode_rows(), got.len());
    assert!(mx.decode_steps() >= (sessions as usize + 3) / 4);
    assert!(mx.decode_occupancy() > 0.0);
    let report = mx.report();
    assert!(report.contains("streaming:"), "{report}");
    assert!(report.contains(&format!("points={sent_points}")), "{report}");
}

/// Multivariate (`d > 1`) sessions end to end through the scheduler and
/// the staged pipeline — the homogeneous-`d` design (DESIGN.md §9): one
/// `d` per serving process, so every batch is homogeneous by
/// construction; the slab is `(capacity, m * d)` with one size per token,
/// and the slab + size-array invariants hold on every step.  Appends that
/// are not whole `d`-channel frames are rejected (see
/// `multivariate_manager_rejects_ragged_frames` in streaming::manager for
/// the intake-level pin).
#[test]
fn multivariate_sessions_stream_end_to_end() {
    let (capacity, m, d) = (4usize, 16usize, 3usize);
    let (tx, rx) = std::sync::mpsc::channel();
    let mut rng = Rng::new(37);
    let sessions = 7u64;
    let mut sent_frames = 0usize;
    for round in 0..5 {
        for id in 0..sessions {
            // uneven feed, always whole frames
            let frames = 2 + ((id as usize + round) % 4);
            sent_frames += frames;
            let pts: Vec<f32> = (0..frames * d).map(|_| rng.normal() as f32).collect();
            tx.send(StreamEvent::Append { session: id, points: pts }).unwrap();
        }
    }
    drop(tx);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let delivered: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = Arc::clone(&delivered);
    let cfg = StreamingConfig { d, ..small_cfg(16, 64, 64) };
    run_stream_stages(
        rx,
        VariantMeta { capacity, m },
        cfg,
        tomers::runtime::WorkerPool::global(),
        Arc::clone(&metrics),
        FaultPolicy::default(),
        move |step| {
            // slab + size-array invariants for homogeneous-d batches
            assert_eq!(step.d, d, "steps carry the process-wide d");
            assert!(step.rows >= 1 && step.rows <= capacity);
            assert_eq!(step.slab.len(), capacity * m * d, "values are (capacity, m*d)");
            assert_eq!(step.sizes.len(), capacity * m, "sizes stay one per token");
            assert_eq!(step.sessions.len(), step.rows);
            for r in 0..step.rows {
                let fill = step.fills[r];
                assert!(fill >= 1 && fill <= m);
                let sizes = &step.sizes[r * m..(r + 1) * m];
                assert!(sizes[m - fill..].iter().all(|&s| s > 0.0), "real tokens sized");
                assert!(sizes[..m - fill].iter().all(|&s| s == 0.0), "padding size 0");
                assert!(
                    step.slab[r * m * d..(r + 1) * m * d].iter().all(|v| v.is_finite()),
                    "interleaved channels stay finite"
                );
            }
            // whole padding rows: values repeat the last real row, size 0
            for p in step.rows..capacity {
                assert_eq!(
                    step.slab[p * m * d..(p + 1) * m * d],
                    step.slab[(step.rows - 1) * m * d..step.rows * m * d]
                );
                assert!(step.sizes[p * m..(p + 1) * m].iter().all(|&s| s == 0.0));
            }
            Ok(vec![vec![2.0f32; 6]; step.rows])
        },
        move |id, f| {
            assert_eq!(f.len(), 6);
            lock(&sink).push(id);
        },
    )
    .unwrap();
    let got = lock(&delivered);
    for id in 0..sessions {
        assert!(got.iter().any(|&s| s == id), "multivariate session {id} starved");
    }
    let mx = lock(&metrics);
    assert_eq!(mx.decode_rows(), got.len());
    let report = mx.report();
    assert!(report.contains(&format!("points={sent_frames}")), "frames counted: {report}");
}
