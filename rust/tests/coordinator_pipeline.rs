//! Integration tests for the staged serving pipeline core — PJRT-free:
//! the execute stage is a closure, so prep (padding + pool-backed
//! premerge driven by the serving `MergeSpec`), double-buffered slab
//! recycling, response plumbing and error isolation are all testable in
//! the default offline build.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use tomers::coordinator::pipeline::{default_host_merge, HostPrep, Pending, PrepJob, VariantMeta};
use tomers::coordinator::{
    pipeline, FaultContext, FaultPolicy, ForecastOutcome, ForecastRequest, Metrics,
};
use tomers::merging::MergeSpec;
use tomers::runtime::WorkerPool;
use tomers::util::Rng;

fn request(id: u64, context: Vec<f32>) -> (Pending, mpsc::Receiver<tomers::coordinator::ForecastResponse>) {
    let (rtx, rrx) = mpsc::channel();
    ((ForecastRequest { id, context }, Instant::now(), rtx), rrx)
}

fn meta(capacity: usize, m: usize) -> VariantMeta {
    VariantMeta { capacity, m }
}

#[test]
fn prep_pads_exact_length_contexts() {
    let pool = WorkerPool::global();
    let mut hp = HostPrep::new(2, default_host_merge());
    let meta = meta(4, 16);
    let mut rng = Rng::new(41);
    let mut batch = Vec::new();
    let mut ctxs = Vec::new();
    for id in 0..2u64 {
        let ctx: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
        ctxs.push(ctx.clone());
        let (p, _rx) = request(id, ctx);
        batch.push(p);
    }
    let mut slab = Vec::new();
    let premerged = hp.prep_into(pool, &batch, &meta, &mut slab).expect("prep");
    assert_eq!(premerged, 0);
    assert_eq!(slab.len(), 4 * 16);
    assert_eq!(&slab[0..16], ctxs[0].as_slice());
    assert_eq!(&slab[16..32], ctxs[1].as_slice());
    // padding repeats the last real row
    assert_eq!(&slab[32..48], ctxs[1].as_slice());
    assert_eq!(&slab[48..64], ctxs[1].as_slice());
}

#[test]
fn prep_premerges_long_contexts_to_reference_semantics() {
    let pool = WorkerPool::global();
    let k = 4;
    let spec = MergeSpec::fixed_r(Vec::new(), k);
    let mut hp = HostPrep::new(3, spec.clone());
    let (len, m) = (96usize, 24usize);
    let meta = meta(3, m);
    let mut rng = Rng::new(42);
    let mut batch = Vec::new();
    let mut ctxs = Vec::new();
    for id in 0..3u64 {
        let ctx: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
        ctxs.push(ctx.clone());
        let (p, _rx) = request(id, ctx);
        batch.push(p);
    }
    let mut slab = Vec::new();
    let premerged = hp.prep_into(pool, &batch, &meta, &mut slab).expect("prep");
    assert_eq!(premerged, 3);
    assert_eq!(slab.len(), 3 * m);
    // each row must equal the single-sequence plan of the derived premerge
    // spec (which the differential suite ties to merging::reference)
    let mut plan = spec.premerge_to(len, m).unwrap().compile(len, 1).unwrap();
    for (i, ctx) in ctxs.iter().enumerate() {
        let want = plan.run(ctx, &vec![1.0; len]);
        assert_eq!(want.sizes.len(), m);
        assert_eq!(&slab[i * m..(i + 1) * m], want.tokens.as_slice(), "row {i}");
    }
}

#[test]
fn prep_rejects_ragged_and_overlong_when_disabled() {
    let pool = WorkerPool::global();
    let meta = meta(4, 16);
    let mut slab = Vec::new();

    // MergeSpec::off disables premerging: over-length contexts bounce
    let mut hp = HostPrep::new(1, MergeSpec::off());
    let (a, _ra) = request(0, vec![0.5; 32]);
    assert!(hp.prep_into(pool, &[a], &meta, &mut slab).is_err(), "premerge disabled");

    let mut hp = HostPrep::new(1, default_host_merge());
    let (a, _ra) = request(0, vec![0.5; 16]);
    let (b, _rb) = request(1, vec![0.5; 18]);
    assert!(hp.prep_into(pool, &[a, b], &meta, &mut slab).is_err(), "ragged batch");

    let (a, _ra) = request(0, vec![0.5; 8]);
    assert!(hp.prep_into(pool, &[a], &meta, &mut slab).is_err(), "short context");
}

/// End-to-end through `run_stages` with a synthetic device: responses
/// arrive with the right ids/rows, premerged slabs reach the executor,
/// and a failing batch poisons nothing — its clients get a terminal
/// `Failed` response (DESIGN.md §10), never a silently dropped channel.
#[test]
fn staged_pipeline_serves_and_isolates_failures() {
    let pool = WorkerPool::global();
    let (capacity, m, len) = (2usize, 12usize, 48usize);
    let metas: BTreeMap<String, VariantMeta> =
        [("v".to_string(), meta(capacity, m))].into_iter().collect();
    let mut rng = Rng::new(43);

    let (jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(2);
    let mut receivers = Vec::new();
    let mut feed = Vec::new();
    for b in 0..5u64 {
        let mut batch = Vec::new();
        for i in 0..capacity as u64 {
            let ctx: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
            let (p, rx) = request(b * 10 + i, ctx);
            batch.push(p);
            receivers.push((b, b * 10 + i, rx));
        }
        feed.push(PrepJob { variant: "v".to_string(), batch });
    }
    // one batch routed to an unknown variant: answered with a terminal
    // error by prep, not fatal and not silently dropped
    let (p, rx_lost) = request(999, (0..len).map(|_| 0.25f32).collect());
    feed.insert(2, PrepJob { variant: "nope".to_string(), batch: vec![p] });

    let feeder = std::thread::spawn(move || {
        for job in feed {
            jobs_tx.send(job).expect("feed");
        }
    });

    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let executed = Arc::new(Mutex::new(Vec::<usize>::new()));
    let exec_log = Arc::clone(&executed);
    let fail_batch = 1u64; // fail the batch whose first id is 10
    // zero retries so the device-call count stays deterministic; the
    // retry loop itself is pinned by tests/serve_faults.rs
    let faults = FaultContext::new(FaultPolicy { max_retries: 0, ..FaultPolicy::default() });
    pipeline::run_stages(
        jobs_rx,
        metas,
        MergeSpec::fixed_r(Vec::new(), 3),
        1,
        pool,
        Arc::clone(&metrics),
        faults,
        move |ready| {
            assert_eq!(ready.slab.len(), capacity * m, "slab shape");
            assert_eq!(ready.premerged, ready.rows, "all contexts premerged");
            exec_log.lock().unwrap().push(ready.rows);
            if ready.batch[0].0.id == fail_batch * 10 {
                anyhow::bail!("synthetic device fault");
            }
            Ok((0..ready.rows).map(|i| vec![i as f32; 7]).collect())
        },
    )
    .expect("run_stages");
    feeder.join().unwrap();

    // every client is answered terminally: the failed batch's clients get
    // `Failed`, everyone else their delivered row
    let (mut ok, mut failed) = (0, 0);
    for (b, id, rx) in receivers {
        let resp = rx.recv().expect("every request gets a terminal response");
        assert_eq!(resp.id, id);
        if b == fail_batch {
            match &resp.outcome {
                ForecastOutcome::Failed(reason) => {
                    assert!(reason.contains("synthetic device fault"), "{reason}");
                }
                other => panic!("failed batch must answer Failed, got {other:?}"),
            }
            assert!(resp.forecast.is_empty(), "no forecast on a failed response");
            failed += 1;
        } else {
            assert!(resp.outcome.is_delivered());
            assert_eq!(resp.forecast.len(), 7);
            assert_eq!(resp.variant, "v");
            assert_eq!(resp.batch_size, capacity);
            ok += 1;
        }
    }
    assert_eq!(ok, 4 * capacity);
    assert_eq!(failed, capacity);
    // the unknown-variant request is answered too, not silently dropped
    let lost = rx_lost.recv().expect("unknown-variant request answered");
    assert!(
        matches!(lost.outcome, ForecastOutcome::Failed(_)),
        "unknown variant is a terminal failure: {:?}",
        lost.outcome
    );
    assert_eq!(executed.lock().unwrap().len(), 5, "all known-variant batches reached the device");
    let mx = metrics.lock().unwrap();
    assert_eq!(mx.served(), 4 * capacity);
    let f = mx.faults();
    assert_eq!(f.exec_faults, 1, "one batch exhausted its (zero) retries");
    assert_eq!(f.failed, capacity as u64 + 1, "failed batch rows + unknown-variant request");
}

/// An invalid serving spec fails `run_stages` up front instead of
/// surfacing as a kernel assert deep in the prep thread — and a spec
/// whose schedule/threshold the prep stage would silently discard is
/// rejected the same way.
#[test]
fn run_stages_rejects_invalid_spec() {
    let pool = WorkerPool::global();
    for (bad, needle) in [
        (MergeSpec { k: 0, ..MergeSpec::off() }, "k must be >= 1"),
        (MergeSpec::single(16, 4), "derived per request shape"),
        (MergeSpec::dynamic(0.9, 4), "derived per request shape"),
    ] {
        let (_jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(1);
        let err = pipeline::run_stages(
            jobs_rx,
            BTreeMap::new(),
            bad,
            1,
            pool,
            Arc::new(Mutex::new(Metrics::new())),
            FaultContext::default(),
            |_ready| Ok(Vec::new()),
        )
        .unwrap_err();
        assert!(err.to_string().contains(needle), "{err}");
    }
}
