//! Differential suite for the streaming incremental causal merge
//! (ISSUE 4 acceptance): across randomized append schedules,
//!
//!   incremental state  ≡  full-sequence causal `MergePlan`  ≡  scalar
//!   reference oracle (`merging::reference::merge_dynamic_reference`)
//!
//! * incremental ≡ plan is **bitwise** for both accumulation modes (the
//!   incremental path calls the kernel's own `token_norm`/`pair_score`
//!   and mirrors its scatter arithmetic op for op);
//! * plan ≡ reference is **bitwise at d == 1** (the kernel's 4-lane
//!   chunked dot degenerates to the reference's serial loop below 4
//!   lanes), decision-exact + 1e-5-close elsewhere (the established
//!   contract of `tests/merging_differential.rs`).
//!
//! The schedule count is deliberately ≥ 1k (the acceptance floor).

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use tomers::merging::reference::merge_dynamic_reference;
use tomers::merging::{Accum, IncrementalMerge, MergeSpec};
use tomers::util::Rng;

/// One randomized append schedule: random threshold, random chunk sizes,
/// occasional non-unit token sizes; after every append the incremental
/// state is compared against a from-scratch plan run, and at the end
/// against the scalar reference.
fn run_schedule(seed: u64, d: usize, accum: Accum, check_every_step: bool) {
    let mut rng = Rng::new(seed);
    let threshold = match rng.below(5) {
        0 => 0.0,
        1 => 0.5,
        2 => 0.9,
        3 => 1.1, // above the cosine ceiling: nothing merges
        _ => rng.uniform(),
    };
    let spec = MergeSpec::dynamic(threshold, 1).with_causal().with_accum(accum);
    let mut inc = IncrementalMerge::new(spec.clone(), d).unwrap();

    let mut tokens: Vec<f32> = Vec::new();
    let mut sizes: Vec<f32> = Vec::new();
    let (mut snap_t, mut snap_s) = (Vec::new(), Vec::new());
    let appends = 1 + rng.below(12);
    for step in 0..appends {
        // chunk sizes 0..=7 tokens: exercises empty appends and repeated
        // odd/even parity boundaries
        let n = rng.below(8);
        for _ in 0..n {
            let row: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let size = if rng.below(4) == 0 { 1.0 + rng.below(3) as f32 } else { 1.0 };
            inc.push_token(&row, size);
            tokens.extend_from_slice(&row);
            sizes.push(size);
        }
        let t = sizes.len();
        if t == 0 || (!check_every_step && step + 1 != appends) {
            continue;
        }
        let full = spec.compile(t, d).unwrap().run(&tokens, &sizes);
        inc.snapshot_into(&mut snap_t, &mut snap_s);
        assert_eq!(
            snap_t, full.tokens,
            "seed {seed} step {step} t={t} d={d} th={threshold} {accum:?}: tokens diverged"
        );
        assert_eq!(snap_s, full.sizes, "seed {seed} step {step}: sizes diverged");
        assert_eq!(inc.raw_len(), t);
        assert_eq!(
            t - inc.merged_pairs(),
            *full.token_counts.last().unwrap(),
            "seed {seed} step {step}: merged-pair count diverged"
        );
    }

    // final state against the scalar reference oracle
    let t = sizes.len();
    if t == 0 || accum != Accum::F64 {
        return; // the reference is f64-only; f32 runs pin incremental ≡ plan
    }
    let (refr, ref_eff) = merge_dynamic_reference(&tokens, &sizes, t, d, 1, threshold);
    inc.snapshot_into(&mut snap_t, &mut snap_s);
    assert_eq!(t - inc.merged_pairs(), ref_eff, "seed {seed}: reference eff diverged");
    assert_eq!(snap_s.len(), refr.sizes.len());
    if d == 1 {
        // exact: see the header
        assert_eq!(snap_t, refr.tokens, "seed {seed}: d=1 must be bitwise vs reference");
        assert_eq!(snap_s, refr.sizes);
    } else {
        for (i, (a, b)) in snap_t.iter().zip(&refr.tokens).enumerate() {
            assert!((a - b).abs() <= 1e-5, "seed {seed} token {i}: {a} vs {b}");
        }
        for (a, b) in snap_s.iter().zip(&refr.sizes) {
            assert!((a - b).abs() <= 1e-5);
        }
    }
}

/// ≥ 1k randomized schedules at d == 1 (the univariate streaming form):
/// every append checked bitwise against the plan, final state bitwise
/// against the scalar reference.
#[test]
fn incremental_equals_plan_and_reference_univariate() {
    for seed in 0..1000 {
        run_schedule(7000 + seed, 1, Accum::F64, true);
    }
}

/// Multivariate schedules: bitwise vs the plan, tolerance vs the
/// reference (chunked-dot rounding).
#[test]
fn incremental_equals_plan_multivariate() {
    for seed in 0..150 {
        let d = [2usize, 3, 5, 8][seed as usize % 4];
        run_schedule(9000 + seed, d, Accum::F64, true);
    }
}

/// F32-accumulation schedules: the incremental path must track the
/// plan's f32 scoring bit for bit too (both call the same `dot_f32`).
#[test]
fn incremental_equals_plan_f32_accum() {
    for seed in 0..150 {
        let d = [1usize, 4][seed as usize % 2];
        run_schedule(11_000 + seed, d, Accum::F32, true);
    }
}

/// Off-mode sessions: the incremental state is a verbatim identity, like
/// an Off plan.
#[test]
fn off_mode_matches_off_plan() {
    let mut rng = Rng::new(5);
    let spec = MergeSpec::off();
    let mut inc = IncrementalMerge::new(spec.clone(), 2).unwrap();
    let mut tokens = Vec::new();
    for _ in 0..50 {
        let row: Vec<f32> = (0..2).map(|_| rng.normal() as f32).collect();
        inc.push_token(&row, 1.0);
        tokens.extend_from_slice(&row);
    }
    let full = spec.compile(25, 2).unwrap().run(&tokens, &vec![1.0; 25]);
    let (mut snap_t, mut snap_s) = (Vec::new(), Vec::new());
    inc.snapshot_into(&mut snap_t, &mut snap_s);
    assert_eq!(snap_t, full.tokens);
    assert_eq!(snap_s, full.sizes);
    assert_eq!(inc.merged_pairs(), 0);
}

/// The plan-side entry point hands back an equivalent incremental state.
#[test]
fn plan_incremental_entry_point() {
    let spec = MergeSpec::dynamic(0.7, 1).with_causal();
    let plan = spec.compile(32, 4).unwrap();
    let mut inc = plan.incremental().unwrap();
    assert_eq!(inc.spec(), &spec);
    assert_eq!(inc.d(), 4);
    inc.append(&[0.5; 8]); // two identical tokens: cosine 1 > 0.7, merges
    assert_eq!(inc.merged_pairs(), 1);
    // fixed-r plans refuse (global top-r cannot be incremental)
    assert!(MergeSpec::single(4, 1)
        .with_causal()
        .compile(32, 4)
        .unwrap()
        .incremental()
        .is_err());
}
