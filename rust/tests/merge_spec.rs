//! The `MergeSpec` rejection table: every class of invalid configuration
//! must fail loudly at `validate()`/`compile()` time with an error naming
//! the offending field — these used to surface as kernel asserts deep in
//! a worker thread, or worse, as silently-clamped nonsense.  Plus the
//! validate-once/run-many lifecycle invariants the serving stack relies
//! on.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use tomers::merging::{MergeMode, MergeSpec};

/// The table itself: (broken spec, substring its error must contain).
#[test]
fn rejection_table() {
    let cases: Vec<(MergeSpec, &str)> = vec![
        // k == 0 in every mode
        (MergeSpec { k: 0, ..MergeSpec::off() }, "k must be >= 1"),
        (MergeSpec::single(4, 0), "k must be >= 1"),
        (MergeSpec::dynamic(0.5, 0), "k must be >= 1"),
        // causal requires adjacent-pair matching
        (MergeSpec::single(4, 2).with_causal(), "causal merging requires k == 1"),
        (MergeSpec::dynamic(0.5, 8).with_causal(), "causal merging requires k == 1"),
        // schedule entries of zero (a "non-decreasing" token schedule)
        (MergeSpec::fixed_r(vec![4, 0, 2], 2), "schedule[1]"),
        (MergeSpec::fixed_r(vec![0], 2), "schedule[0]"),
        // NaN / negative dynamic thresholds
        (MergeSpec::dynamic(f64::NAN, 2), "threshold is NaN"),
        (MergeSpec::dynamic(-0.25, 2), "threshold must be >= 0"),
    ];
    for (i, (spec, needle)) in cases.iter().enumerate() {
        let err = spec.validate().expect_err(&format!("case {i} must fail: {spec:?}"));
        assert!(
            err.to_string().contains(needle),
            "case {i}: error {err:?} does not mention {needle:?}"
        );
        // compile re-runs validation, so the same spec can't sneak into a plan
        assert!(spec.compile(64, 4).is_err(), "case {i} compiled");
    }
}

/// Shape-level rejections: feasibility of the schedule against `(t, d)`.
#[test]
fn compile_rejection_table() {
    let cases: Vec<(MergeSpec, usize, usize, &str)> = vec![
        // r >= t: a single layer can merge at most half the even prefix
        (MergeSpec::single(32, 4), 32, 4, "infeasible"),
        (MergeSpec::single(40, 4), 32, 4, "infeasible"),
        (MergeSpec::single(17, 4), 32, 4, "infeasible"),
        // cumulative overrun in a deep schedule
        (MergeSpec::fixed_r(vec![16, 8, 8], 4), 32, 4, "schedule[2]"),
        // degenerate shapes
        (MergeSpec::off(), 0, 4, "t must be >= 1"),
        (MergeSpec::off(), 4, 0, "d must be >= 1"),
    ];
    for (i, (spec, t, d, needle)) in cases.iter().enumerate() {
        let err = spec.compile(*t, *d).expect_err(&format!("case {i} must fail"));
        assert!(
            err.to_string().contains(needle),
            "case {i}: error {err:?} does not mention {needle:?}"
        );
    }
    // the boundary case is legal: exactly half the even prefix
    assert!(MergeSpec::single(16, 4).compile(32, 4).is_ok());
    assert!(MergeSpec::single(16, 4).compile(33, 4).is_ok());
}

/// Lifecycle: one validated spec compiles into independent plans; an
/// `Off`/identity plan is an exact passthrough; accessors expose the
/// compiled schedule.
#[test]
fn lifecycle_and_accessors() {
    let spec = MergeSpec::fixed_r(vec![8, 4], 3);
    assert_eq!(spec.layers(), 2);
    assert_eq!(spec.total_r(), 12);
    assert!(!spec.is_off());
    let plan = spec.compile(32, 2).unwrap();
    assert_eq!(plan.t(), 32);
    assert_eq!(plan.d(), 2);
    assert_eq!(plan.layer_counts(), &[32, 24, 20]);
    assert_eq!(plan.out_tokens(), 20);
    assert_eq!(plan.spec(), &spec);
    assert_eq!(plan.slots(), 1);
    assert_eq!(plan.with_slots(5).slots(), 5);

    // the same spec compiles against other shapes independently
    assert_eq!(spec.compile(64, 8).unwrap().layer_counts(), &[64, 56, 52]);

    assert_eq!(MergeSpec::off().layers(), 0);
    assert_eq!(MergeSpec::dynamic(0.9, 2).layers(), 1);
}

/// `premerge_to` keeps the template's k/accum/causal and derives a
/// schedule whose compiled plan lands exactly on the target.
#[test]
fn premerge_derivation_hits_target() {
    let tmpl = MergeSpec::fixed_r(Vec::new(), 6);
    for (len, target) in [(768usize, 512usize), (2048, 512), (513, 512), (1001, 100), (512, 512)] {
        let spec = tmpl.premerge_to(len, target).unwrap();
        assert_eq!(spec.k, 6);
        let plan = spec.compile(len, 1).unwrap();
        assert_eq!(plan.out_tokens(), target, "{len} -> {target}");
        match &spec.mode {
            MergeMode::FixedR { schedule } => {
                assert_eq!(schedule.iter().sum::<usize>(), len - target)
            }
            m => panic!("unexpected mode {m:?}"),
        }
    }
}
