//! Integration tests for the worker pool under its real workload: the
//! batched [`MergePlan`] path must be spawn-free after warmup, panic-safe,
//! and correct under stealing/concurrency.  (Pool-internal unit tests live
//! in `src/runtime/pool.rs`; the differential tie to `merging::reference`
//! is in `tests/merging_differential.rs`.)

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};

use tomers::merging::{MergeSpec, PipelineResult};
use tomers::runtime::WorkerPool;
use tomers::util::Rng;

#[test]
fn merge_batches_spawn_no_threads_after_warmup() {
    let pool = WorkerPool::new(3);
    assert_eq!(pool.spawned_threads(), 3);
    let mut rng = Rng::new(71);
    let (b, t, d, r, k) = (8usize, 64usize, 8usize, 16usize, 4usize);
    let tokens: Vec<f32> = (0..b * t * d).map(|_| rng.normal() as f32).collect();
    let sizes = vec![1.0f32; b * t];
    let spec = MergeSpec::single(r, k);
    let mut plan = spec.compile(t, d).expect("plan").with_slots(3);
    let mut outs: Vec<PipelineResult> = Vec::new();
    // warmup + 30 steady-state batches: the spawn counter must not move
    for round in 0..31 {
        plan.run_batch_into(&pool, &tokens, &sizes, b, &mut outs);
        assert_eq!(pool.spawned_threads(), 3, "round {round} spawned a thread");
    }
    // stealing/help bookkeeping adds up: 31 rounds x 3 chunk tasks
    assert_eq!(pool.tasks_executed(), 31 * 3);
    // and the results are still the single-sequence plan's
    let mut single = spec.compile(t, d).expect("plan");
    for i in 0..b {
        let want = single.run(
            &tokens[i * t * d..(i + 1) * t * d],
            &sizes[i * t..(i + 1) * t],
        );
        assert_eq!(outs[i].tokens, want.tokens, "seq {i}");
        assert_eq!(outs[i].slot_map, want.slot_map);
    }
}

#[test]
fn panicking_batch_does_not_wedge_later_merges() {
    let pool = WorkerPool::new(2);
    // a task batch that panics...
    let err = catch_unwind(AssertUnwindSafe(|| {
        let tasks: Vec<Box<dyn FnOnce() + Send>> =
            vec![Box::new(|| {}), Box::new(|| panic!("boom")), Box::new(|| {})];
        pool.run(tasks);
    }));
    assert!(err.is_err());
    // ...must leave the pool fully serviceable for real merge work
    let mut rng = Rng::new(72);
    let (b, t, d) = (6usize, 40usize, 4usize);
    let tokens: Vec<f32> = (0..b * t * d).map(|_| rng.normal() as f32).collect();
    let sizes = vec![1.0f32; b * t];
    let mut plan = MergeSpec::single(10, 3).compile(t, d).expect("plan").with_slots(2);
    let mut outs = Vec::new();
    plan.run_batch_into(&pool, &tokens, &sizes, b, &mut outs);
    assert_eq!(outs.len(), b);
    for out in &outs {
        assert_eq!(out.tokens.len(), (t - 10) * d);
    }
    assert_eq!(pool.spawned_threads(), 2);
}

#[test]
fn many_concurrent_plans_share_one_pool() {
    let pool = WorkerPool::new(2);
    let done = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for seed in 0..4u64 {
            let done = &done;
            let pool = &pool;
            s.spawn(move || {
                let mut rng = Rng::new(100 + seed);
                let (b, t, d, r, k) = (5usize, 30usize, 5usize, 7usize, 3usize);
                let tokens: Vec<f32> = (0..b * t * d).map(|_| rng.normal() as f32).collect();
                let sizes = vec![1.0f32; b * t];
                let spec = MergeSpec::single(r, k);
                let mut plan = spec.compile(t, d).expect("plan").with_slots(4);
                let mut single = spec.compile(t, d).expect("plan");
                let mut outs = Vec::new();
                for _ in 0..10 {
                    plan.run_batch_into(pool, &tokens, &sizes, b, &mut outs);
                    for i in 0..b {
                        let want = single.run(
                            &tokens[i * t * d..(i + 1) * t * d],
                            &sizes[i * t..(i + 1) * t],
                        );
                        assert_eq!(outs[i].tokens, want.tokens);
                    }
                }
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
    });
    assert_eq!(done.load(Ordering::SeqCst), 4);
    assert_eq!(pool.spawned_threads(), 2, "concurrency must not spawn threads");
}
