//! Differential tests: the optimized zero-allocation kernel
//! (`merging::kernel`, reached through the public wrappers) must be
//! semantically identical to the legacy scalar reference
//! (`merging::reference`) — tokens and sizes within 1e-5, slot maps
//! exactly equal — across ~10k randomized `(t, d, r, k)` cases, including
//! odd `t`, `r = 0`, `k >= t/2` (global matching) and size-weighted
//! inputs.  Plus NaN regression, batch/pipeline consistency and the causal
//! `k = 1` adjacency invariant on the optimized path.

use tomers::merging::kernel::{merge_dynamic_scratch, merge_fixed_r_scratch};
use tomers::merging::reference::{
    match_tokens_reference, merge_dynamic_reference, merge_fixed_r_reference,
};
use tomers::merging::{
    match_tokens, merge_batch, MergePipeline, MergeResult, MergeScratch,
};
use tomers::util::Rng;

fn rand_tokens(rng: &mut Rng, t: usize, d: usize) -> Vec<f32> {
    (0..t * d).map(|_| rng.normal() as f32).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str, case: usize) {
    assert_eq!(a.len(), b.len(), "{what} length, case {case}");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol,
            "{what}[{i}] diverged in case {case}: {x} vs {y}"
        );
    }
}

/// The headline differential property: ~10k randomized cases, optimized
/// kernel (warm shared scratch) vs legacy reference.
#[test]
fn differential_optimized_equals_reference() {
    let mut rng = Rng::new(0xD1FF);
    let mut scratch = MergeScratch::new();
    let mut out = MergeResult::default();
    for case in 0..10_000 {
        // mix odd/even t, include tiny and mid sizes
        let t = 2 + rng.below(62);
        let d = 1 + rng.below(16);
        let t2 = (t - t % 2) / 2;
        // r sweeps the full feasible range, with r = 0 and r = t2 included;
        // every 8th case forces r = 0, every 9th forces r = t2
        let r = if case % 8 == 0 {
            0
        } else if case % 9 == 0 {
            t2
        } else {
            rng.below(t2 + 1)
        };
        // k includes 1, the band interior, and k >= t/2 (global)
        let k = if case % 5 == 0 { t2.max(1) + rng.below(4) } else { 1 + rng.below(t2.max(1)) };
        let tokens = rand_tokens(&mut rng, t, d);
        // half the cases size-weighted, half unit sizes
        let sizes: Vec<f32> = if case % 2 == 0 {
            vec![1.0; t]
        } else {
            (0..t).map(|_| 1.0 + rng.below(4) as f32).collect()
        };

        merge_fixed_r_scratch(&tokens, &sizes, t, d, r, k, &mut scratch, &mut out);
        let refr = merge_fixed_r_reference(&tokens, &sizes, t, d, r, k);

        assert_eq!(
            out.slot_map, refr.slot_map,
            "slot_map diverged in case {case} (t={t} d={d} r={r} k={k})"
        );
        assert_close(&out.tokens, &refr.tokens, 1e-5, "tokens", case);
        assert_close(&out.sizes, &refr.sizes, 1e-5, "sizes", case);
    }
}

/// Matching itself: same best indices and scores (to fp reassociation).
#[test]
fn differential_matching_equals_reference() {
    let mut rng = Rng::new(0xA7C4);
    for case in 0..2_000 {
        let t = 2 + rng.below(80);
        let d = 1 + rng.below(12);
        let t2 = (t - t % 2) / 2;
        let k = 1 + rng.below(t2.max(1) + 2);
        let tokens = rand_tokens(&mut rng, t, d);
        let (scores, best) = match_tokens(&tokens, t, d, k);
        let (ref_scores, ref_best) = match_tokens_reference(&tokens, t, d, k);
        assert_eq!(best, ref_best, "best diverged in case {case} (t={t} d={d} k={k})");
        for (i, (s, rs)) in scores.iter().zip(&ref_scores).enumerate() {
            assert!(
                (s - rs).abs() <= 1e-9,
                "score[{i}] diverged in case {case}: {s} vs {rs}"
            );
        }
    }
}

/// Dynamic merging: same effective token count and slot map for a sweep of
/// thresholds.
#[test]
fn differential_dynamic_equals_reference() {
    let mut rng = Rng::new(0xD14A);
    let mut scratch = MergeScratch::new();
    let mut out = MergeResult::default();
    for case in 0..1_000 {
        let t = 4 + rng.below(40);
        let d = 1 + rng.below(8);
        let t2 = (t - t % 2) / 2;
        let k = 1 + rng.below(t2.max(1));
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes: Vec<f32> = (0..t).map(|_| 1.0 + rng.below(3) as f32).collect();
        for th in [-1.1, -0.5, 0.0, 0.3, 0.7, 0.95, 1.1] {
            let eff = merge_dynamic_scratch(&tokens, &sizes, t, d, k, th, &mut scratch, &mut out);
            let (refr, ref_eff) = merge_dynamic_reference(&tokens, &sizes, t, d, k, th);
            assert_eq!(eff, ref_eff, "eff diverged in case {case} th={th}");
            assert_eq!(out.slot_map, refr.slot_map, "slot_map diverged in case {case} th={th}");
            assert_close(&out.tokens, &refr.tokens, 1e-5, "tokens", case);
        }
    }
}

/// NaN hardening: the legacy top-r sort used `partial_cmp().unwrap()`, a
/// latent panic (NaN never actually reached `scores` — the matching
/// update rejects it — but nothing pinned that down).  Both paths now use
/// a total order and must survive NaN-containing tokens with intact
/// shape invariants.
#[test]
fn differential_nan_inputs_no_panic() {
    let mut rng = Rng::new(0x4A4);
    let mut scratch = MergeScratch::new();
    let mut out = MergeResult::default();
    for case in 0..200 {
        let t = 6 + rng.below(30);
        let d = 1 + rng.below(6);
        let t2 = (t - t % 2) / 2;
        let r = 1 + rng.below(t2);
        let k = 1 + rng.below(t2);
        let mut tokens = rand_tokens(&mut rng, t, d);
        // poison a few entries (sometimes whole rows)
        for _ in 0..1 + rng.below(4) {
            tokens[rng.below(t * d)] = f32::NAN;
        }
        let sizes = vec![1.0f32; t];
        merge_fixed_r_scratch(&tokens, &sizes, t, d, r, k, &mut scratch, &mut out);
        let refr = merge_fixed_r_reference(&tokens, &sizes, t, d, r, k);
        for res in [(&out.slot_map, out.sizes.len()), (&refr.slot_map, refr.sizes.len())] {
            let (slot_map, n_out) = res;
            assert_eq!(n_out, t - r, "case {case}");
            assert_eq!(slot_map.len(), t);
            assert!(slot_map.iter().all(|&s| s < t - r), "case {case}");
        }
    }
}

/// The causal `k = 1` adjacency invariant holds on the optimized kernel:
/// every merge group spans at most two adjacent original positions.
#[test]
fn optimized_causal_k1_adjacency() {
    let mut rng = Rng::new(0xCA51);
    let mut scratch = MergeScratch::new();
    let mut out = MergeResult::default();
    for case in 0..500 {
        let t = 6 + rng.below(50);
        let d = 1 + rng.below(8);
        let t2 = (t - t % 2) / 2;
        let r = rng.below(t2) + 1;
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes = vec![1.0f32; t];
        merge_fixed_r_scratch(&tokens, &sizes, t, d, r, 1, &mut scratch, &mut out);
        for s in 0..t - r {
            let members: Vec<usize> = (0..t).filter(|&p| out.slot_map[p] == s).collect();
            let span = members.last().unwrap() - members.first().unwrap();
            assert!(span <= 1, "case {case}: k=1 group spans {span} > 1: {members:?}");
        }
    }
}

/// The batched entry point agrees with the reference per sequence.
#[test]
fn differential_batch_equals_reference() {
    let mut rng = Rng::new(0xBA7C);
    for case in 0..100 {
        let b = 1 + rng.below(9);
        let t = 4 + rng.below(40);
        let d = 1 + rng.below(8);
        let t2 = (t - t % 2) / 2;
        let r = rng.below(t2 + 1);
        let k = 1 + rng.below(t2.max(1));
        let tokens = rand_tokens(&mut rng, b * t, d);
        let sizes: Vec<f32> = (0..b * t).map(|_| 1.0 + rng.below(2) as f32).collect();
        let outs = merge_batch(&tokens, &sizes, b, t, d, r, k);
        assert_eq!(outs.len(), b);
        for i in 0..b {
            let refr = merge_fixed_r_reference(
                &tokens[i * t * d..(i + 1) * t * d],
                &sizes[i * t..(i + 1) * t],
                t,
                d,
                r,
                k,
            );
            assert_eq!(outs[i].slot_map, refr.slot_map, "case {case} seq {i}");
            assert_close(&outs[i].tokens, &refr.tokens, 1e-5, "tokens", case);
            assert_close(&outs[i].sizes, &refr.sizes, 1e-5, "sizes", case);
        }
    }
}

/// The pipeline agrees with repeated single-shot reference merges plus
/// hand-composed slot maps.
#[test]
fn differential_pipeline_equals_layered_reference() {
    let mut rng = Rng::new(0x919E);
    let mut pipe = MergePipeline::new();
    for case in 0..200 {
        let t = 8 + rng.below(56);
        let d = 1 + rng.below(8);
        let k = 1 + rng.below(8);
        let layers = 1 + rng.below(5);
        let r = 1 + rng.below(8);
        let q = 2 + rng.below(6);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes: Vec<f32> = (0..t).map(|_| 1.0 + rng.below(2) as f32).collect();

        let res = pipe.run(&tokens, &sizes, t, d, k, r, layers, q);

        let counts = tomers::merging::merge_schedule(t, r, layers, q);
        let mut cur_tokens = tokens.clone();
        let mut cur_sizes = sizes.clone();
        let mut composed: Vec<usize> = (0..t).collect();
        let mut cur_t = t;
        for w in counts.windows(2) {
            let m = merge_fixed_r_reference(&cur_tokens, &cur_sizes, cur_t, d, w[0] - w[1], k);
            for slot in composed.iter_mut() {
                *slot = m.slot_map[*slot];
            }
            cur_tokens = m.tokens;
            cur_sizes = m.sizes;
            cur_t = w[1];
        }
        assert_eq!(res.token_counts, counts, "case {case}");
        assert_eq!(res.slot_map, composed, "case {case}");
        assert_close(&res.tokens, &cur_tokens, 1e-4, "tokens", case);
        assert_close(&res.sizes, &cur_sizes, 1e-4, "sizes", case);
    }
}
