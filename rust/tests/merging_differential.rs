//! Differential tests: the plan-driven API (`MergeSpec` -> `MergePlan`)
//! and the optimized zero-allocation kernel must be semantically identical
//! to the legacy scalar reference (`merging::reference`) — tokens and
//! sizes within 1e-5, slot maps exactly equal — across ~10k randomized
//! `(t, d, r, k)` cases, including odd `t`, `r = 0`, `k >= t/2` (global
//! matching) and size-weighted inputs.  The deprecated one-shot wrappers
//! are exercised on purpose (hence the file-wide `allow(deprecated)`):
//! the acceptance criterion is plan ≡ legacy entry points ≡ reference,
//! bit-for-bit on slot maps.  Plus NaN regression, batch/plan consistency
//! and the causal `k = 1` adjacency invariant on the optimized path.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
#![allow(deprecated)]
use tomers::merging::kernel::{
    match_tokens_scratch_accum, merge_dynamic_scratch, merge_dynamic_scratch_accum,
    merge_fixed_r_scratch, merge_fixed_r_scratch_accum,
};
use tomers::merging::reference::{
    match_tokens_reference, merge_dynamic_reference, merge_fixed_r_reference,
};
use tomers::merging::{
    match_tokens, merge_batch, merge_dynamic, merge_fixed_r, Accum, MergeResult, MergeScratch,
    MergeSpec,
};
use tomers::runtime::WorkerPool;
use tomers::util::Rng;

fn rand_tokens(rng: &mut Rng, t: usize, d: usize) -> Vec<f32> {
    (0..t * d).map(|_| rng.normal() as f32).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str, case: usize) {
    assert_eq!(a.len(), b.len(), "{what} length, case {case}");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol,
            "{what}[{i}] diverged in case {case}: {x} vs {y}"
        );
    }
}

/// The headline differential property: ~10k randomized cases, optimized
/// kernel (warm shared scratch) vs legacy reference.
#[test]
fn differential_optimized_equals_reference() {
    let mut rng = Rng::new(0xD1FF);
    let mut scratch = MergeScratch::new();
    let mut out = MergeResult::default();
    for case in 0..10_000 {
        // mix odd/even t, include tiny and mid sizes
        let t = 2 + rng.below(62);
        let d = 1 + rng.below(16);
        let t2 = (t - t % 2) / 2;
        // r sweeps the full feasible range, with r = 0 and r = t2 included;
        // every 8th case forces r = 0, every 9th forces r = t2
        let r = if case % 8 == 0 {
            0
        } else if case % 9 == 0 {
            t2
        } else {
            rng.below(t2 + 1)
        };
        // k includes 1, the band interior, and k >= t/2 (global)
        let k = if case % 5 == 0 { t2.max(1) + rng.below(4) } else { 1 + rng.below(t2.max(1)) };
        let tokens = rand_tokens(&mut rng, t, d);
        // half the cases size-weighted, half unit sizes
        let sizes: Vec<f32> = if case % 2 == 0 {
            vec![1.0; t]
        } else {
            (0..t).map(|_| 1.0 + rng.below(4) as f32).collect()
        };

        merge_fixed_r_scratch(&tokens, &sizes, t, d, r, k, &mut scratch, &mut out);
        let refr = merge_fixed_r_reference(&tokens, &sizes, t, d, r, k);

        assert_eq!(
            out.slot_map, refr.slot_map,
            "slot_map diverged in case {case} (t={t} d={d} r={r} k={k})"
        );
        assert_close(&out.tokens, &refr.tokens, 1e-5, "tokens", case);
        assert_close(&out.sizes, &refr.sizes, 1e-5, "sizes", case);
    }
}

/// The acceptance differential: a compiled `MergePlan` must bit-match the
/// legacy one-shot entry point AND the reference oracle — same slot maps,
/// tokens/sizes within fp tolerance — on randomized single-step cases.
/// Plan-based execution is the only production path after the redesign,
/// so this is the test that proves the migration changed no semantics.
#[test]
fn differential_plan_equals_legacy_and_reference() {
    let mut rng = Rng::new(0x9A51);
    for case in 0..2_000 {
        let t = 2 + rng.below(60);
        let d = 1 + rng.below(12);
        let t2 = (t - t % 2) / 2;
        let r = if case % 7 == 0 { 0 } else { rng.below(t2 + 1) };
        let k = 1 + rng.below(t2.max(1) + 2);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes: Vec<f32> = (0..t).map(|_| 1.0 + rng.below(3) as f32).collect();

        let spec = if r == 0 { MergeSpec::off() } else { MergeSpec::single(r, k) };
        let mut plan = spec.compile(t, d).expect("plan compiles");
        let planned = plan.run(&tokens, &sizes);
        let legacy = merge_fixed_r(&tokens, &sizes, t, d, r, k);
        let refr = merge_fixed_r_reference(&tokens, &sizes, t, d, r, k);

        // plan == legacy wrapper: bitwise (identical kernel underneath)
        assert_eq!(planned.slot_map, legacy.slot_map, "case {case} (t={t} d={d} r={r} k={k})");
        assert_eq!(planned.tokens, legacy.tokens, "case {case}");
        assert_eq!(planned.sizes, legacy.sizes, "case {case}");
        // plan == reference oracle: slot maps exact, values within fp tol
        assert_eq!(planned.slot_map, refr.slot_map, "case {case}");
        assert_close(&planned.tokens, &refr.tokens, 1e-5, "tokens", case);
        assert_close(&planned.sizes, &refr.sizes, 1e-5, "sizes", case);
        assert_eq!(*planned.token_counts.last().unwrap(), t - r, "case {case}");
    }
}

/// Dynamic plans against the legacy wrapper and the reference, over the
/// spec-valid threshold range (the wrapper additionally accepts negative
/// thresholds; those stay covered by `differential_dynamic_equals_reference`).
#[test]
fn differential_dynamic_plan_equals_legacy_and_reference() {
    let mut rng = Rng::new(0x9A52);
    for case in 0..500 {
        let t = 4 + rng.below(40);
        let d = 1 + rng.below(8);
        let t2 = (t - t % 2) / 2;
        let k = 1 + rng.below(t2.max(1));
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes: Vec<f32> = (0..t).map(|_| 1.0 + rng.below(3) as f32).collect();
        for th in [0.0, 0.3, 0.7, 0.95, 1.1] {
            let mut plan = MergeSpec::dynamic(th, k).compile(t, d).expect("dynamic plan");
            let planned = plan.run(&tokens, &sizes);
            let (legacy, leg_eff) = merge_dynamic(&tokens, &sizes, t, d, k, th);
            let (refr, ref_eff) = merge_dynamic_reference(&tokens, &sizes, t, d, k, th);
            let eff = *planned.token_counts.last().unwrap();
            assert_eq!(eff, leg_eff, "case {case} th={th}");
            assert_eq!(eff, ref_eff, "case {case} th={th}");
            assert_eq!(planned.slot_map, legacy.slot_map, "case {case} th={th}");
            assert_eq!(planned.tokens, legacy.tokens);
            assert_eq!(planned.slot_map, refr.slot_map);
            assert_close(&planned.tokens, &refr.tokens, 1e-5, "tokens", case);
        }
    }
}

/// Matching itself: same best indices and scores (to fp reassociation).
#[test]
fn differential_matching_equals_reference() {
    let mut rng = Rng::new(0xA7C4);
    for case in 0..2_000 {
        let t = 2 + rng.below(80);
        let d = 1 + rng.below(12);
        let t2 = (t - t % 2) / 2;
        let k = 1 + rng.below(t2.max(1) + 2);
        let tokens = rand_tokens(&mut rng, t, d);
        let (scores, best) = match_tokens(&tokens, t, d, k);
        let (ref_scores, ref_best) = match_tokens_reference(&tokens, t, d, k);
        assert_eq!(best, ref_best, "best diverged in case {case} (t={t} d={d} k={k})");
        for (i, (s, rs)) in scores.iter().zip(&ref_scores).enumerate() {
            assert!(
                (s - rs).abs() <= 1e-9,
                "score[{i}] diverged in case {case}: {s} vs {rs}"
            );
        }
    }
}

/// Dynamic merging: same effective token count and slot map for a sweep of
/// thresholds — including the negative "merge everything" range only the
/// kernel/legacy surface accepts.
#[test]
fn differential_dynamic_equals_reference() {
    let mut rng = Rng::new(0xD14A);
    let mut scratch = MergeScratch::new();
    let mut out = MergeResult::default();
    for case in 0..1_000 {
        let t = 4 + rng.below(40);
        let d = 1 + rng.below(8);
        let t2 = (t - t % 2) / 2;
        let k = 1 + rng.below(t2.max(1));
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes: Vec<f32> = (0..t).map(|_| 1.0 + rng.below(3) as f32).collect();
        for th in [-1.1, -0.5, 0.0, 0.3, 0.7, 0.95, 1.1] {
            let eff = merge_dynamic_scratch(&tokens, &sizes, t, d, k, th, &mut scratch, &mut out);
            let (refr, ref_eff) = merge_dynamic_reference(&tokens, &sizes, t, d, k, th);
            assert_eq!(eff, ref_eff, "eff diverged in case {case} th={th}");
            assert_eq!(out.slot_map, refr.slot_map, "slot_map diverged in case {case} th={th}");
            assert_close(&out.tokens, &refr.tokens, 1e-5, "tokens", case);
        }
    }
}

/// NaN hardening: the legacy top-r sort used `partial_cmp().unwrap()`, a
/// latent panic (NaN never actually reached `scores` — the matching
/// update rejects it — but nothing pinned that down).  Both paths now use
/// a total order and must survive NaN-containing tokens with intact
/// shape invariants.
#[test]
fn differential_nan_inputs_no_panic() {
    let mut rng = Rng::new(0x4A4);
    let mut scratch = MergeScratch::new();
    let mut out = MergeResult::default();
    for case in 0..200 {
        let t = 6 + rng.below(30);
        let d = 1 + rng.below(6);
        let t2 = (t - t % 2) / 2;
        let r = 1 + rng.below(t2);
        let k = 1 + rng.below(t2);
        let mut tokens = rand_tokens(&mut rng, t, d);
        // poison a few entries (sometimes whole rows)
        for _ in 0..1 + rng.below(4) {
            tokens[rng.below(t * d)] = f32::NAN;
        }
        let sizes = vec![1.0f32; t];
        merge_fixed_r_scratch(&tokens, &sizes, t, d, r, k, &mut scratch, &mut out);
        let refr = merge_fixed_r_reference(&tokens, &sizes, t, d, r, k);
        for res in [(&out.slot_map, out.sizes.len()), (&refr.slot_map, refr.sizes.len())] {
            let (slot_map, n_out) = res;
            assert_eq!(n_out, t - r, "case {case}");
            assert_eq!(slot_map.len(), t);
            assert!(slot_map.iter().all(|&s| s < t - r), "case {case}");
        }
        // the plan path inherits the hardening
        let planned = MergeSpec::single(r, k).compile(t, d).expect("plan").run(&tokens, &sizes);
        assert_eq!(planned.slot_map, out.slot_map, "case {case}");
    }
}

/// The causal `k = 1` adjacency invariant holds on the plan path: every
/// merge group spans at most two adjacent original positions.
#[test]
fn causal_plan_k1_adjacency() {
    let mut rng = Rng::new(0xCA51);
    for case in 0..500 {
        let t = 6 + rng.below(50);
        let d = 1 + rng.below(8);
        let t2 = (t - t % 2) / 2;
        let r = rng.below(t2) + 1;
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes = vec![1.0f32; t];
        let mut plan = MergeSpec::single(r, 1).with_causal().compile(t, d).expect("causal plan");
        let res = plan.run(&tokens, &sizes);
        for s in 0..t - r {
            let members: Vec<usize> = (0..t).filter(|&p| res.slot_map[p] == s).collect();
            let span = members.last().unwrap() - members.first().unwrap();
            assert!(span <= 1, "case {case}: k=1 group spans {span} > 1: {members:?}");
        }
    }
}

/// The batched plan path and the deprecated one-shot `merge_batch` agree
/// with the reference per sequence.
#[test]
fn differential_batch_equals_reference() {
    let mut rng = Rng::new(0xBA7C);
    let pool = WorkerPool::new(3);
    for case in 0..100 {
        let b = 1 + rng.below(9);
        let t = 4 + rng.below(40);
        let d = 1 + rng.below(8);
        let t2 = (t - t % 2) / 2;
        let r = rng.below(t2 + 1);
        let k = 1 + rng.below(t2.max(1));
        let tokens = rand_tokens(&mut rng, b * t, d);
        let sizes: Vec<f32> = (0..b * t).map(|_| 1.0 + rng.below(2) as f32).collect();
        let outs = merge_batch(&tokens, &sizes, b, t, d, r, k);
        assert_eq!(outs.len(), b);
        let spec = if r == 0 { MergeSpec::off() } else { MergeSpec::single(r, k) };
        let mut plan = spec.compile(t, d).expect("plan").with_slots(4);
        let mut plan_outs = Vec::new();
        plan.run_batch_into(&pool, &tokens, &sizes, b, &mut plan_outs);
        for i in 0..b {
            let refr = merge_fixed_r_reference(
                &tokens[i * t * d..(i + 1) * t * d],
                &sizes[i * t..(i + 1) * t],
                t,
                d,
                r,
                k,
            );
            assert_eq!(outs[i].slot_map, refr.slot_map, "case {case} seq {i}");
            assert_close(&outs[i].tokens, &refr.tokens, 1e-5, "tokens", case);
            assert_close(&outs[i].sizes, &refr.sizes, 1e-5, "sizes", case);
            // the pool-batched plan is bitwise the one-shot wrapper
            assert_eq!(plan_outs[i].slot_map, outs[i].slot_map, "case {case} seq {i}");
            assert_eq!(plan_outs[i].tokens, outs[i].tokens);
            assert_eq!(plan_outs[i].sizes, outs[i].sizes);
        }
    }
}

/// The f32-accumulation banded dot stays within its documented tolerance
/// of the f64 scores (see `Accum` in kernel.rs: 1e-5 for standardized
/// inputs at d <= 64, measured headroom ~50x).
#[test]
fn differential_f32_accum_scores_within_tolerance() {
    let mut rng = Rng::new(0xF32);
    let mut s64 = MergeScratch::new();
    let mut s32 = MergeScratch::new();
    for case in 0..1_000 {
        let t = 4 + rng.below(60);
        let d = 1 + rng.below(64);
        let t2 = (t - t % 2) / 2;
        let k = 1 + rng.below(t2.max(1));
        let tokens = rand_tokens(&mut rng, t, d);
        match_tokens_scratch_accum(&tokens, t, d, k, &mut s64, Accum::F64);
        match_tokens_scratch_accum(&tokens, t, d, k, &mut s32, Accum::F32);
        for (i, (a, b)) in s64.scores().iter().zip(s32.scores()).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5,
                "score[{i}] case {case} (t={t} d={d} k={k}): {a} vs {b}"
            );
        }
    }
}

/// A plan built with `with_accum(Accum::F32)` runs the f32 matching stage
/// in every mode: identical to the f32 kernel call, fixed and dynamic.
#[test]
fn differential_f32_plan_matches_f32_kernel() {
    let mut rng = Rng::new(0xF34);
    let mut scratch = MergeScratch::new();
    let mut out = MergeResult::default();
    for case in 0..300 {
        let t = 4 + rng.below(40);
        let d = 1 + rng.below(16);
        let t2 = (t - t % 2) / 2;
        let r = 1 + rng.below(t2.max(1));
        let k = 1 + rng.below(t2.max(1));
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes = vec![1.0f32; t];

        let mut plan =
            MergeSpec::single(r, k).with_accum(Accum::F32).compile(t, d).expect("f32 plan");
        let planned = plan.run(&tokens, &sizes);
        merge_fixed_r_scratch_accum(
            &tokens, &sizes, t, d, r, k, &mut scratch, &mut out, Accum::F32,
        );
        assert_eq!(planned.slot_map, out.slot_map, "case {case} (t={t} d={d} r={r} k={k})");
        assert_eq!(planned.tokens, out.tokens);

        let th = 0.5;
        let mut dplan =
            MergeSpec::dynamic(th, k).with_accum(Accum::F32).compile(t, d).expect("f32 dyn plan");
        let dplanned = dplan.run(&tokens, &sizes);
        let eff = merge_dynamic_scratch_accum(
            &tokens, &sizes, t, d, k, th, &mut scratch, &mut out, Accum::F32,
        );
        assert_eq!(*dplanned.token_counts.last().unwrap(), eff, "case {case}");
        assert_eq!(dplanned.slot_map, out.slot_map);
    }
}

/// When every selection the matcher makes has a clear f64 margin (no
/// near-ties, neither in the per-token partner choice nor in the top-r
/// cut), the f32 path must merge the exact same pairs and produce the
/// same outputs.  Near-tie cases are skipped: there the f32 path may
/// legitimately pick the other member of the tie.
#[test]
fn differential_f32_accum_merge_matches_on_clear_margins() {
    /// All banded candidate scores per A-token, f64 cosine (the margin
    /// oracle — mirrors the kernel's matching loop).
    fn banded_scores(tokens: &[f32], t: usize, d: usize, k: usize) -> Vec<Vec<f64>> {
        let t2 = (t - t % 2) / 2;
        let k = k.clamp(1, t2.max(1));
        (0..t2)
            .map(|i| {
                let a = &tokens[(2 * i) * d..(2 * i + 1) * d];
                let lo = i.saturating_sub(k - 1);
                let hi = (i + k - 1).min(t2 - 1);
                (lo..=hi)
                    .map(|j| {
                        let b = &tokens[(2 * j + 1) * d..(2 * j + 2) * d];
                        let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
                        for x in 0..d {
                            dot += a[x] as f64 * b[x] as f64;
                            na += (a[x] as f64).powi(2);
                            nb += (b[x] as f64).powi(2);
                        }
                        dot / (na.sqrt() * nb.sqrt() + 1e-8)
                    })
                    .collect()
            })
            .collect()
    }

    const MARGIN: f64 = 1e-3; // 100x the documented 1e-5 score tolerance
    let mut rng = Rng::new(0xF33);
    let mut scratch = MergeScratch::new();
    let mut out64 = MergeResult::default();
    let mut out32 = MergeResult::default();
    let mut checked = 0usize;
    for _case in 0..800 {
        let t = 6 + rng.below(50);
        let d = 4 + rng.below(32);
        let t2 = (t - t % 2) / 2;
        let r = 1 + rng.below(t2);
        let k = 1 + rng.below(t2);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes = vec![1.0f32; t];

        let cand = banded_scores(&tokens, t, d, k);
        // partner-choice margins: best vs second-best within each band
        let partner_clear = cand.iter().all(|c| {
            let mut s = c.clone();
            s.sort_by(|a, b| b.total_cmp(a));
            s.len() < 2 || s[0] - s[1] > MARGIN
        });
        // top-r margin: r-th selected best-score vs best rejected one
        let mut best: Vec<f64> = cand
            .iter()
            .map(|c| c.iter().cloned().fold(f64::NEG_INFINITY, f64::max))
            .collect();
        best.sort_by(|a, b| b.total_cmp(a));
        let cut_clear = r >= t2 || best[r - 1] - best[r] > MARGIN;
        if !partner_clear || !cut_clear {
            continue;
        }

        merge_fixed_r_scratch_accum(
            &tokens, &sizes, t, d, r, k, &mut scratch, &mut out64, Accum::F64,
        );
        merge_fixed_r_scratch_accum(
            &tokens, &sizes, t, d, r, k, &mut scratch, &mut out32, Accum::F32,
        );
        assert_eq!(out64.slot_map, out32.slot_map, "t={t} d={d} r={r} k={k}");
        assert_close(&out64.tokens, &out32.tokens, 1e-4, "tokens", checked);
        assert_close(&out64.sizes, &out32.sizes, 1e-4, "sizes", checked);
        checked += 1;
    }
    assert!(checked > 300, "too many skipped cases ({checked} checked)");
}

/// Batched multi-layer plans on the worker pool agree with repeated
/// single-shot *reference* merges plus hand-composed slot maps, per
/// sequence — the pool-backed plan is tied to the same oracle as
/// everything else.
#[test]
fn differential_batch_plan_on_pool_equals_reference() {
    let mut rng = Rng::new(0x9001);
    let pool = WorkerPool::new(3);
    for case in 0..60 {
        let b = 1 + rng.below(7);
        let t = 10 + rng.below(40);
        let d = 1 + rng.below(6);
        let k = 1 + rng.below(6);
        let layers = 1 + rng.below(4);
        // feasible-by-construction schedule: each layer merges at most a
        // quarter of the tokens alive at that depth
        let mut rs: Vec<usize> = Vec::new();
        {
            let mut cur = t;
            for _ in 0..layers {
                let feasible = (cur - cur % 2) / 2;
                let r_l = 1 + rng.below(feasible.min(4));
                rs.push(r_l);
                cur -= r_l;
            }
        }
        let tokens = rand_tokens(&mut rng, b * t, d);
        let sizes: Vec<f32> = (0..b * t).map(|_| 1.0 + rng.below(2) as f32).collect();

        let mut plan = MergeSpec::fixed_r(rs.clone(), k).compile(t, d).expect("plan").with_slots(4);
        let mut outs = Vec::new();
        plan.run_batch_into(&pool, &tokens, &sizes, b, &mut outs);
        assert_eq!(outs.len(), b);

        for i in 0..b {
            let seq_tokens = &tokens[i * t * d..(i + 1) * t * d];
            let seq_sizes = &sizes[i * t..(i + 1) * t];
            let mut cur_tokens = seq_tokens.to_vec();
            let mut cur_sizes = seq_sizes.to_vec();
            let mut composed: Vec<usize> = (0..t).collect();
            let mut cur_t = t;
            for &r_l in &rs {
                let m = merge_fixed_r_reference(&cur_tokens, &cur_sizes, cur_t, d, r_l, k);
                for slot in composed.iter_mut() {
                    *slot = m.slot_map[*slot];
                }
                cur_tokens = m.tokens;
                cur_sizes = m.sizes;
                cur_t -= r_l;
            }
            assert_eq!(outs[i].slot_map, composed, "case {case} seq {i}");
            assert_close(&outs[i].tokens, &cur_tokens, 1e-4, "tokens", case);
            assert_close(&outs[i].sizes, &cur_sizes, 1e-4, "sizes", case);
            assert_eq!(*outs[i].token_counts.last().unwrap(), cur_t);
        }
    }
}

/// A multi-layer plan (the paper's static rule via `layered_for`) agrees
/// with repeated single-shot reference merges plus hand-composed slot
/// maps.
#[test]
fn differential_layered_plan_equals_layered_reference() {
    let mut rng = Rng::new(0x919E);
    for case in 0..200 {
        let t = 8 + rng.below(56);
        let d = 1 + rng.below(8);
        let k = 1 + rng.below(8);
        let layers = 1 + rng.below(5);
        let r = 1 + rng.below(8);
        let q = 2 + rng.below(6);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes: Vec<f32> = (0..t).map(|_| 1.0 + rng.below(2) as f32).collect();

        let mut plan = MergeSpec::layered_for(t, r, layers, q, k).compile(t, d).expect("plan");
        let res = plan.run(&tokens, &sizes);

        let counts = tomers::merging::merge_schedule(t, r, layers, q);
        let mut cur_tokens = tokens.clone();
        let mut cur_sizes = sizes.clone();
        let mut composed: Vec<usize> = (0..t).collect();
        let mut cur_t = t;
        for w in counts.windows(2) {
            if w[0] == w[1] {
                continue; // floor-limited layer: dropped from the spec
            }
            let m = merge_fixed_r_reference(&cur_tokens, &cur_sizes, cur_t, d, w[0] - w[1], k);
            for slot in composed.iter_mut() {
                *slot = m.slot_map[*slot];
            }
            cur_tokens = m.tokens;
            cur_sizes = m.sizes;
            cur_t = w[1];
        }
        assert_eq!(*res.token_counts.last().unwrap(), *counts.last().unwrap(), "case {case}");
        assert_eq!(res.slot_map, composed, "case {case}");
        assert_close(&res.tokens, &cur_tokens, 1e-4, "tokens", case);
        assert_close(&res.sizes, &cur_sizes, 1e-4, "sizes", case);
    }
}
