//! Integration test: the Rust training loop drives a real train-step
//! artifact and the loss decreases.  Skipped when artifacts are missing.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::path::PathBuf;

use tomers::bench::forecast_suite::dataset;
use tomers::data::Split;
use tomers::runtime::{Engine, WeightStore};
use tomers::train;
use tomers::util::Rng;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("fc_transformer_L2__train.hlo.txt").exists().then_some(dir)
}

#[test]
fn training_reduces_loss_and_updates_weights() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let engine = Engine::new(&dir).unwrap();
    let mut model = engine.load("fc_transformer_L2__train").unwrap();
    let init = WeightStore::load(&dir.join("fc_transformer_L2.weights.bin")).unwrap();
    model.bind_weights(&init).unwrap();
    let batch = model.manifest.batch();
    let ds = dataset("etth1", 4000, 192, 96, Split::Train, 1);
    let mut rng = Rng::new(11);
    let report = train::train_loop(
        &mut model,
        &init,
        30,
        |_| {
            let idx: Vec<usize> = (0..batch).map(|_| rng.below(ds.len())).collect();
            ds.batch(&idx)
        },
        |_, _| true,
    )
    .unwrap();
    // chunked artifacts quantize the step count up to a chunk multiple
    assert!(report.steps >= 30 && report.steps <= 34, "steps {}", report.steps);
    let first = report.losses[0];
    let last = *report.losses.last().unwrap();
    assert!(
        last < first * 0.8,
        "loss did not decrease: {first} -> {last}"
    );
    // weights actually changed
    let w0 = init.tensors.values().next().unwrap();
    let name = init.tensors.keys().next().unwrap();
    let w1 = report.final_weights.get(name).unwrap();
    assert_ne!(w0, w1, "weights unchanged after training");
}

#[test]
fn early_stopping_halts_loop() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let engine = Engine::new(&dir).unwrap();
    let mut model = engine.load("fc_transformer_L2__train").unwrap();
    let init = WeightStore::load(&dir.join("fc_transformer_L2.weights.bin")).unwrap();
    model.bind_weights(&init).unwrap();
    let batch = model.manifest.batch();
    let ds = dataset("etth1", 4000, 192, 96, Split::Train, 1);
    let mut rng = Rng::new(12);
    let report = train::train_loop(
        &mut model,
        &init,
        100,
        |_| {
            let idx: Vec<usize> = (0..batch).map(|_| rng.below(ds.len())).collect();
            ds.batch(&idx)
        },
        |step, _| step < 4, // request stop after 5 steps
    )
    .unwrap();
    // stop honoured at chunk granularity
    assert!(report.steps >= 5 && report.steps <= 8, "steps {}", report.steps);
}
