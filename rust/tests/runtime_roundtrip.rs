//! Integration tests: HLO artifact -> PJRT compile -> execute, verified
//! numerically against Python golden outputs (written by `aot.py`).
//!
//! These tests require `make artifacts` to have produced the artifact
//! directory; they are skipped (with a message) when it is absent so
//! `cargo test` stays green on a fresh checkout.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::path::PathBuf;

use tomers::runtime::{Engine, WeightStore};
use tomers::tensor::Tensor;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let has_any = dir.read_dir().map(|mut d| d.next().is_some()).unwrap_or(false);
    has_any.then_some(dir)
}

/// Load golden (inputs, outputs) recorded by aot.py for `name`.
fn golden(dir: &PathBuf, name: &str) -> Option<(Vec<Tensor>, Vec<Tensor>)> {
    let path = dir.join(format!("{name}.golden.bin"));
    let ws = WeightStore::load(&path).ok()?;
    let mut ins = Vec::new();
    let mut outs = Vec::new();
    for i in 0.. {
        match ws.get(&format!("in{i}")) {
            Ok(t) => ins.push(t.clone()),
            Err(_) => break,
        }
    }
    for i in 0.. {
        match ws.get(&format!("out{i}")) {
            Ok(t) => outs.push(t.clone()),
            Err(_) => break,
        }
    }
    Some((ins, outs))
}

fn max_abs_diff(a: &Tensor, b: &Tensor) -> f64 {
    match (a, b) {
        (Tensor::F32 { data: x, .. }, Tensor::F32 { data: y, .. }) => x
            .iter()
            .zip(y)
            .map(|(p, q)| (p - q).abs() as f64)
            .fold(0.0, f64::max),
        (Tensor::I32 { data: x, .. }, Tensor::I32 { data: y, .. }) => x
            .iter()
            .zip(y)
            .map(|(p, q)| (p - q).abs() as f64)
            .fold(0.0, f64::max),
        _ => f64::INFINITY,
    }
}

fn roundtrip(name: &str, tol: f64) {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP {name}: no artifacts dir (run `make artifacts`)");
        return;
    };
    let Some((ins, want)) = golden(&dir, name) else {
        eprintln!("SKIP {name}: no golden file");
        return;
    };
    let engine = Engine::new(&dir).expect("pjrt engine");
    let model = engine.load_with_weights(name).expect("load artifact");
    let got = model.execute(&ins).expect("execute");
    assert_eq!(got.len(), want.len(), "{name}: output arity");
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.shape(), w.shape(), "{name}: out{i} shape");
        let d = max_abs_diff(g, w);
        assert!(d < tol, "{name}: out{i} max|diff| = {d} > {tol}");
    }
    println!("{name}: OK ({} outputs)", got.len());
}

#[test]
fn forecast_transformer_with_merging_matches_python() {
    roundtrip("fc_transformer_L2__r16", 2e-4);
}

#[test]
fn forecast_autoformer_no_merging_matches_python() {
    roundtrip("fc_autoformer_L2__r0", 5e-3); // FFT autocorrelation: XLA-version FFT precision
}

#[test]
fn chronos_with_merging_matches_python() {
    roundtrip("chronos_s__r64", 5e-3); // logits: argmax-stable tolerance
}

#[test]
fn chronos_pallas_kernels_roundtrip() {
    // The interpret-mode Pallas kernel path compiled into HLO and executed
    // by the Rust PJRT runtime — proves L1 -> L3 composition.
    roundtrip("chronos_s__r64_pallas", 5e-3);
}

#[test]
fn mamba_pallas_scan_roundtrip() {
    roundtrip("mamba_L2s__r64_pallas", 1e-3);
}

#[test]
fn hyena_local_merging_matches_python() {
    roundtrip("hyena_L4__r64_k1", 1e-2); // long FFT convs: XLA-version FFT precision
}

#[test]
fn patchtst_matches_python() {
    roundtrip("patchtst_L2__r4", 2e-4);
}

#[test]
fn manifest_shape_validation_rejects_bad_input() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::new(&dir).unwrap();
    let Ok(model) = engine.load_with_weights("fc_transformer_L2__r16") else {
        return;
    };
    let bad = Tensor::zeros_f32(&[1, 2, 3]);
    assert!(model.execute(&[bad]).is_err());
    assert!(model.execute(&[]).is_err());
}

#[test]
fn engine_lists_artifacts() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::new(&dir).unwrap();
    let names = engine.available().unwrap();
    assert!(names.iter().any(|n| n.starts_with("chronos")));
}
