//! `TOMERS_FORCE_SCALAR=1` must route dispatch to the scalar path — a
//! single test in its own integration binary (its own process), because
//! the environment variable is latched by the one-time probe behind
//! `simd::active_isa`: setting it here, before anything touches the
//! kernel, is only sound when no other test in the same process can win
//! the race to initialize the cache.  Keep this file to exactly one
//! `#[test]`.
//!
//! The assertion goes through the dispatch *report* (the observable
//! contract), never through timing.

use tomers::merging::kernel::{merge_fixed_r_scratch, Accum};
use tomers::merging::simd::{self, Isa};
use tomers::merging::{MergeResult, MergeScratch};

#[test]
fn force_scalar_env_routes_to_scalar_path() {
    // First action in the process: latch the override before any kernel
    // call can initialize the dispatch cache.
    std::env::set_var("TOMERS_FORCE_SCALAR", "1");

    assert_eq!(simd::active_isa(), Isa::Scalar);
    let report = simd::dispatch_report();
    assert!(
        report.starts_with("isa=scalar "),
        "env override did not reach the dispatch report: {report}"
    );
    // the metrics surface exposes the same line serving operators see
    let metrics = tomers::coordinator::metrics::Metrics::new().report();
    assert!(metrics.contains("kernel: isa=scalar "), "{metrics}");

    // And the kernel actually runs (to completion, correctly) under the
    // override: output must equal the explicit scalar primitives' result.
    let (t, d, r, k) = (32usize, 7usize, 8usize, 3usize);
    let tokens: Vec<f32> = (0..t * d).map(|i| ((i * 37 % 97) as f32 - 48.0) / 17.0).collect();
    let sizes = vec![1.0f32; t];
    let mut scratch = MergeScratch::new();
    let mut out = MergeResult::default();
    merge_fixed_r_scratch(&tokens, &sizes, t, d, r, k, &mut scratch, &mut out);
    assert_eq!(out.slot_map.len(), t);
    assert_eq!(out.tokens.len(), (t - r) * d);
    // spot-check one score against the hand-built scalar computation
    let a = &tokens[0..d];
    let b = &tokens[d..2 * d];
    let expect = simd::dot_f64(Isa::Scalar, a, b)
        / (simd::sumsq_f64(Isa::Scalar, a).sqrt() * simd::sumsq_f64(Isa::Scalar, b).sqrt() + 1e-8);
    let got = tomers::merging::kernel::pair_score(
        a,
        b,
        tomers::merging::kernel::token_norm(a, Accum::F64),
        tomers::merging::kernel::token_norm(b, Accum::F64),
        Accum::F64,
    );
    assert_eq!(got.to_bits(), expect.to_bits());
}
