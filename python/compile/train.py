"""Training-step graphs (fwd + bwd + Adam), lowered so Rust drives training.

Each model family gets a ``train_step(params, m, v, step, batch...) ->
(params', m', v', loss)`` pure function.  The optimiser is Adam
(Kingma & Ba 2015 — paper table 6) implemented inline so the whole update
is one HLO module; Rust feeds the flattened state back in every step.

Training *with* token merging (§5.2) is the same graph with a merging
config on the model — merging is differentiable (segment-sum averaging),
so gradients flow through merged tokens.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adam_update(params, grads, m, v, step, *, lr=1e-3, b1=0.9, b2=0.999,
                eps=1e-8, decay=0.97, decay_every=100.0):
    """One Adam step with exponential LR decay (gamma=0.97, table 6)."""
    step = step + 1.0
    lr_t = lr * decay ** (step / decay_every)
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, v, grads)
    mhat = jax.tree.map(lambda mm: mm / (1 - b1**step), m)
    vhat = jax.tree.map(lambda vv: vv / (1 - b2**step), v)
    params = jax.tree.map(
        lambda p, mh, vh: p - lr_t * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return params, m, v


def mse_loss(pred, target):
    return jnp.mean((pred - target) ** 2)


def ce_loss(logits, ids):
    logp = jax.nn.log_softmax(logits, -1)
    return -jnp.mean(jnp.take_along_axis(logp, ids[..., None], -1))


def make_forecast_train_step(forward_batch, cfg, *, lr=1e-3):
    """Forecaster train step: batch (x (b,m,n), y (b,p,n)) -> MSE."""

    def loss_fn(params, xb, yb):
        return mse_loss(forward_batch(params, xb, cfg), yb)

    def train_step(params, m, v, step, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
        params, m, v = adam_update(params, grads, m, v, step, lr=lr)
        return params, m, v, loss

    return train_step


def make_chronos_train_step(forward_batch, tokenize, cfg, *, lr=1e-3):
    """Chronos train step: context (b, m) + target values (b, p); the
    target is quantized with the *context* scale inside the graph
    (the Chronos recipe) and trained with cross-entropy."""
    from .models import chronos as Ch

    def loss_fn(params, xb, yb):
        out = forward_batch(params, xb, cfg)
        logits = out[0]

        def quant(x, y):
            _, scale = tokenize(x, cfg)
            ys = jnp.clip(y / scale, -cfg.clip, cfg.clip)
            ids = jnp.round((ys + cfg.clip) / (2 * cfg.clip) * (cfg.vocab - 1))
            return ids.astype(jnp.int32)

        ids = jax.vmap(quant)(xb, yb)
        return ce_loss(logits, ids)

    def train_step(params, m, v, step, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
        params, m, v = adam_update(params, grads, m, v, step, lr=lr)
        return params, m, v, loss

    return train_step


def make_classify_train_step(forward_batch, cfg, *, lr=1e-3):
    """Genomic classifier train step: ids (b, m) int32, labels (b,) int32."""

    def loss_fn(params, xb, yb):
        return ce_loss(forward_batch(params, xb, cfg), yb)

    def train_step(params, m, v, step, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
        params, m, v = adam_update(params, grads, m, v, step, lr=lr)
        return params, m, v, loss

    return train_step


def make_chunked(step_fn, chunk):
    """Scan ``chunk`` optimiser steps inside one graph.

    PJRT 0.5.1 hands back the root tuple as a single buffer, forcing a full
    host round-trip of the parameters per execution; scanning K steps per
    execution amortises that mandatory transfer K-fold (EXPERIMENTS.md
    §Perf).  Batches arrive stacked: xs (K, b, ...), ys (K, b, ...);
    returns (params, m, v, losses (K,)).
    """

    def chunk_step(params, m, v, step0, xs, ys):
        def body(carry, xy):
            params, m, v, s = carry
            x, y = xy
            params, m, v, loss = step_fn(params, m, v, s, x, y)
            return (params, m, v, s + 1.0), loss

        (params, m, v, _), losses = jax.lax.scan(
            body, (params, m, v, step0), (xs, ys))
        return params, m, v, losses

    return chunk_step
