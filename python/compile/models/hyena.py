"""Hyena state-space classifier for the genomic experiment (§5.4, table 3).

Order-2 Hyena operator (Poli et al. 2023): input projections split the
embedded sequence into (v, x1, x2) streams; implicit long convolutions with
filters generated from positional features by a small FFN under an
exponential decay window; data-controlled gating between stages::

    z = v;  z = x1 * fftconv(z, h1);  z = x2 * fftconv(z, h2)

Token merging is applied **after the Hyena operator** of each block with
``k = 1`` (§4: "we merge tokens after the Hyena or Mamba operator and
choose k = 1 to not introduce an operation with quadratic complexity").
Global merging (``k = t/2``) is also exposed for the table-3 comparison.
Classification: mean-pool (size-weighted) -> linear head.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import merging
from . import common as C


@dataclass(frozen=True)
class HyenaConfig:
    vocab: int = 5            # A C G T N
    m: int = 1024             # sequence length (paper: 16000; DESIGN.md §7)
    n_classes: int = 2
    d: int = 64
    order: int = 2
    filter_d: int = 32        # filter-FFN hidden width
    layers: int = 4
    r: int = 0                # merges per block
    k: int = 1                # 1 = local/causal, >= t/2 = global
    q_min: int = 16
    metric: str = "cos"


def init_params(key, cfg: HyenaConfig):
    ks = iter(jax.random.split(key, 4 + 6 * cfg.layers))
    p = {
        "embed": C.embedding_init(next(ks), cfg.vocab, cfg.d),
        "head": C.dense_init(next(ks), cfg.d, cfg.n_classes),
        "blocks": [],
    }
    for _ in range(cfg.layers):
        p["blocks"].append(
            {
                "in_proj": C.dense_init(next(ks), cfg.d, (cfg.order + 1) * cfg.d),
                "filter_fc1": C.dense_init(next(ks), 3, cfg.filter_d),
                "filter_fc2": C.dense_init(next(ks), cfg.filter_d, cfg.order * cfg.d),
                "decay": jnp.linspace(1.0, 4.0, cfg.order * cfg.d, dtype=jnp.float32),
                "out_proj": C.dense_init(next(ks), cfg.d, cfg.d),
                "ln": C.layernorm_init(cfg.d),
                "ln2": C.layernorm_init(cfg.d),
                "mlp": C.mlp_init(next(ks), cfg.d, 2 * cfg.d),
            }
        )
    return C.strip_static(p)


def _filters(bp, t, cfg: HyenaConfig):
    """Implicit filters h: (order, t, d) from positional features."""
    pos = jnp.arange(t, dtype=jnp.float32) / t
    feat = jnp.stack([pos, jnp.sin(2 * jnp.pi * pos), jnp.cos(2 * jnp.pi * pos)], -1)
    h = C.dense(bp["filter_fc2"], jnp.sin(C.dense(bp["filter_fc1"], feat)))
    h = h.reshape(t, cfg.order, cfg.d).transpose(1, 0, 2)      # (order, t, d)
    window = jnp.exp(-bp["decay"].reshape(cfg.order, 1, cfg.d)
                     * pos[None, :, None])
    return h * window


def fftconv(z, h):
    """Causal depthwise long convolution via FFT: (t, d) x (t, d) -> (t, d).

    Padded to the next power of two: merged layers have non-pow2 lengths
    (e.g. 960) and XLA's Bluestein fallback for those is several times
    slower — pow2 padding keeps the FFT on the fast path regardless of the
    merge schedule (EXPERIMENTS.md §Perf).
    """
    t = z.shape[0]
    n = 1 << (2 * t - 1).bit_length()
    fz = jnp.fft.rfft(z, n=n, axis=0)
    fh = jnp.fft.rfft(h, n=n, axis=0)
    return jnp.fft.irfft(fz * fh, n=n, axis=0)[:t]


def hyena_operator(bp, x, cfg: HyenaConfig):
    t = x.shape[0]
    streams = C.dense(bp["in_proj"], x).reshape(t, cfg.order + 1, cfg.d)
    v = streams[:, 0]
    h = _filters(bp, t, cfg)
    z = v
    for o in range(cfg.order):
        gate = jax.nn.silu(streams[:, o + 1])
        z = gate * fftconv(z, h[o])
    return C.dense(bp["out_proj"], z)


def forward(params, ids, cfg: HyenaConfig):
    """ids: (m,) int32 nucleotides -> logits (n_classes,)."""
    h = params["embed"]["e"][ids]
    sizes = jnp.ones((cfg.m,), jnp.float32)
    counts = merging.merge_schedule(cfg.m, r=cfg.r, num_layers=cfg.layers,
                                    q=cfg.q_min)
    for li, bp in enumerate(params["blocks"]):
        h = h + hyena_operator(bp, C.layernorm(bp["ln"], h), cfg)
        r_l = counts[li] - counts[li + 1]
        if r_l > 0:
            k_l = cfg.k if cfg.k > 0 else max(1, h.shape[0] // 2)
            res = merging.merge_fixed_r(h, sizes, r=r_l, k=k_l, metric=cfg.metric)
            h, sizes = res.x, res.sizes
        h = h + C.mlp(bp["mlp"], C.layernorm(bp["ln2"], h))
    pooled = jnp.sum(h * sizes[:, None], 0) / jnp.sum(sizes)
    return C.dense(params["head"], pooled)


def forward_batch(params, idsb, cfg: HyenaConfig):
    return jax.vmap(lambda i: forward(params, i, cfg))(idsb)
