"""Decoder-only forecaster (TimesFM/Das et al. 2023 style) with causal
token merging — the architecture class the paper's causal-merging claim
(§3 "the first viable token merging scheme for transformer decoders")
exists for.

Patch-tokenized univariate context -> stack of causal decoder blocks with
**causal merging (k=1) between self-attention and MLP in every block** ->
unmerge -> per-position multi-patch forecast head.  The final context token
predicts the horizon.  Every token's receptive field stays strictly causal
through merging (merged pairs land at the later source position).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import merging
from . import common as C


@dataclass(frozen=True)
class DecoderOnlyConfig:
    m: int = 512              # context length
    p: int = 64               # horizon
    patch_len: int = 16       # input patch (token) size
    d: int = 64
    heads: int = 4
    layers: int = 4
    mlp_hidden: int = 128
    r: int = 0                # causal merges per block (k = 1 always)
    q_min: int = 4
    metric: str = "cos"

    @property
    def n_tokens(self):
        assert self.m % self.patch_len == 0
        return self.m // self.patch_len


def token_counts(cfg: DecoderOnlyConfig):
    return merging.merge_schedule(cfg.n_tokens, r=cfg.r, num_layers=cfg.layers,
                                  q=cfg.q_min)


def init_params(key, cfg: DecoderOnlyConfig):
    ks = iter(jax.random.split(key, 4 + 4 * cfg.layers))
    p = {
        "embed": C.dense_init(next(ks), cfg.patch_len, cfg.d),
        "head": C.dense_init(next(ks), cfg.d, cfg.p),
        "blocks": [],
    }
    for _ in range(cfg.layers):
        p["blocks"].append(
            {
                "attn": C.mha_init(next(ks), cfg.d, cfg.heads),
                "ln1": C.layernorm_init(cfg.d),
                "ln2": C.layernorm_init(cfg.d),
                "mlp": C.mlp_init(next(ks), cfg.d, cfg.mlp_hidden),
            }
        )
    return C.strip_static(p)


def forward(params, x, cfg: DecoderOnlyConfig):
    """x: (m,) univariate context -> forecast (p,).

    Mean-scaled like Chronos so weights transfer across amplitudes.
    """
    scale = jnp.mean(jnp.abs(x)) + 1e-6
    xs = (x / scale).reshape(cfg.n_tokens, cfg.patch_len)
    h = C.dense(params["embed"], xs) + C.sinusoidal_pe(cfg.n_tokens, cfg.d)
    sizes = jnp.ones((cfg.n_tokens,), jnp.float32)
    counts = token_counts(cfg)
    for li, bp in enumerate(params["blocks"]):
        t_l = h.shape[0]
        bias = C.causal_mask(t_l) + C.size_bias(sizes, t_l)
        h = h + C.mha(bp["attn"], C.layernorm(bp["ln1"], h),
                      C.layernorm(bp["ln1"], h), heads=cfg.heads, bias=bias)
        r_l = counts[li] - counts[li + 1]
        if r_l > 0:
            res = merging.merge_causal(h, sizes, r=r_l, metric=cfg.metric)
            h, sizes = res.x, res.sizes
        h = h + C.mlp(bp["mlp"], C.layernorm(bp["ln2"], h))
    # the most recent token predicts the horizon (it is never merged away:
    # B-tokens survive, and the final position is a B-token or the excluded
    # odd leftover)
    return C.dense(params["head"], h[-1]) * scale


def forward_batch(params, xb, cfg: DecoderOnlyConfig):
    return jax.vmap(lambda x: forward(params, x, cfg))(xb)
