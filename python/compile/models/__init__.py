"""Layer-2 JAX model zoo (build-time only; lowered to HLO by aot.py)."""
