"""The five table-1 attention flavours + shared encoder/decoder blocks.

Each flavour implements the *mechanism that defines the architecture* at
our scale (DESIGN.md §8):

* ``vanilla``       — full softmax attention (Vaswani et al.) via Pallas.
* ``informer``      — ProbSparse: only the top-u "active" queries (by the
  max-minus-mean sparsity measure) attend; lazy queries output mean(V).
* ``autoformer``    — auto-correlation attention: FFT-based correlation
  R(tau), aggregate V rolled by the top-c delays, softmax-weighted; plus
  series decomposition around the block.
* ``fedformer``     — frequency-enhanced block: rFFT, learned complex
  per-mode mixing on a fixed subset of modes, irFFT; plus decomposition.
* ``nonstationary`` — series stationarization + de-stationary attention
  (learned tau/delta re-injecting the removed statistics).

All flavours accept merged-token ``bias`` (mask + log-size) so ToMe
proportional attention composes with every mechanism, and all are pure
``f(params, x)`` functions with static shapes.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..merging import rank_desc, topk_desc
from . import common as C

# ---------------------------------------------------------------------------
# Attention flavours.  Signature: attn(p, xq, xkv, *, heads, bias) -> (tq, d)


def vanilla_attention(p, xq, xkv, *, heads, bias):
    return C.mha(p, xq, xkv, heads=heads, bias=bias)


def probsparse_attention(p, xq, xkv, *, heads, bias, factor=5):
    """Informer ProbSparse self-attention.

    Sparsity measure M(q) = max_j(s_qj) - mean_j(s_qj); the top
    ``u = factor * ln(t)`` queries attend exactly, the rest emit mean(V)
    (the Informer "lazy" path).  At our sequence lengths we score against
    all keys (the paper samples; exactness only sharpens the measure).
    """
    tq = xq.shape[0]
    u = min(tq, max(1, int(factor * math.log(max(tq, 2)))))
    q = C.split_heads(C.dense(p["wq"], xq), heads)
    k = C.split_heads(C.dense(p["wk"], xkv), heads)
    v = C.split_heads(C.dense(p["wv"], xkv), heads)
    dh = q.shape[-1]
    logits = jnp.einsum("htd,hsd->hts", q, k) / math.sqrt(dh) + bias[None]
    m = jnp.max(logits, -1) - jnp.mean(logits, -1)          # (h, tq)
    # rank-based active mask (scatter- and sort-free; see merging.rank_desc)
    active = rank_desc(m) < u
    w = jax.nn.softmax(logits, -1)
    full = jnp.einsum("hts,hsd->htd", w, v)
    lazy = jnp.broadcast_to(jnp.mean(v, axis=1, keepdims=True), full.shape)
    o = jnp.where(active[:, :, None], full, lazy)
    return C.dense(p["wo"], C.join_heads(o))


def autocorrelation_attention(p, xq, xkv, *, heads, bias, factor=1):
    """Autoformer auto-correlation: time-delay aggregation.

    R(tau) = mean_d irfft(rfft(q) conj(rfft(k))); roll V by the top-c
    delays and combine with softmax(R).  ``bias`` enters as a size-aware
    rescale of the correlation through its diagonal-free part being
    irrelevant here (auto-correlation is sequence-level, not pairwise), so
    we apply the log-size bias on the value aggregation weights instead.
    """
    t = xq.shape[0]
    c = min(t, max(1, int(factor * math.log(max(t, 2)) * 2)))
    q = C.split_heads(C.dense(p["wq"], xq), heads)
    k = C.split_heads(C.dense(p["wk"], xkv), heads)
    v = C.split_heads(C.dense(p["wv"], xkv), heads)
    fq = jnp.fft.rfft(q, axis=1)
    fk = jnp.fft.rfft(k, axis=1)
    r = jnp.fft.irfft(fq * jnp.conj(fk), n=t, axis=1)        # (h, t, dh)
    r = jnp.mean(r, axis=-1)                                 # (h, t) corr per tau
    # Keep only the top-c delays via a rank mask, softmax their scores into
    # per-delay weights w_full (h, t), then aggregate V over all delays as a
    # circular cross-correlation computed by FFT:
    #   out[i] = sum_tau w[tau] * v[(i + tau) mod t]
    # This is both gather-free (old-HLO compatible) and closer to
    # Autoformer's own FFT formulation than explicit rolls.
    masked = jnp.where(rank_desc(r) < c, r, -jnp.inf)
    w_full = jax.nn.softmax(masked, axis=-1)                 # (h, t)
    fw = jnp.fft.rfft(w_full, axis=1)                        # (h, f)
    fv = jnp.fft.rfft(v, axis=1)                             # (h, f, dh)
    o = jnp.fft.irfft(jnp.conj(fw)[:, :, None] * fv, n=t, axis=1)
    return C.dense(p["wo"], C.join_heads(o))


def frequency_attention(p, xq, xkv, *, heads, bias, modes=16):
    """FEDformer frequency-enhanced block (FEB-f, self path).

    rFFT along time, learned complex mixing on a fixed low+spread subset of
    ``modes`` modes (per-mode diagonal over channels — DESIGN.md §7 notes
    this simplification of FEDformer's random per-mode matrices), irFFT.
    """
    t, d = xq.shape
    x = C.dense(p["wv"], xq)
    f = jnp.fft.rfft(x, axis=0)                              # (t//2+1, d)
    nf = f.shape[0]
    m = min(modes, nf)
    # Fixed deterministic mode subset: low frequencies + strided spread.
    idx = jnp.concatenate(
        [jnp.arange(m // 2), (jnp.arange(m - m // 2) * max(1, nf // max(1, m)))]
    )
    idx = jnp.clip(idx, 0, nf - 1)
    wr, wi = p["freq_wr"]["w"][:m], p["freq_wi"]["w"][:m]    # (m, d)
    sel = f[idx]                                             # (m, d)
    mixed = sel * (wr + 1j * wi)
    f2 = jnp.zeros_like(f).at[idx].set(mixed)
    y = jnp.fft.irfft(f2, n=t, axis=0)
    return C.dense(p["wo"], y)


def destationary_attention(p, xq, xkv, *, heads, bias, tau, delta):
    """Non-stationary Transformer de-stationary attention:
    softmax((Q K^T * tau + delta) / sqrt(dh)) V with learned scalar tau and
    per-key delta recovered from the removed statistics."""
    q = C.split_heads(C.dense(p["wq"], xq), heads)
    k = C.split_heads(C.dense(p["wk"], xkv), heads)
    v = C.split_heads(C.dense(p["wv"], xkv), heads)
    dh = q.shape[-1]
    logits = (jnp.einsum("htd,hsd->hts", q, k) * tau + delta[None, None, :]) \
        / math.sqrt(dh) + bias[None]
    o = jnp.einsum("hts,hsd->htd", jax.nn.softmax(logits, -1), v)
    return C.dense(p["wo"], C.join_heads(o))


ATTENTION = {
    "transformer": vanilla_attention,
    "informer": probsparse_attention,
    "autoformer": autocorrelation_attention,
    "fedformer": frequency_attention,
    "nonstationary": vanilla_attention,  # tau/delta injected by the model
}

# Architectures that wrap attention blocks in series decomposition.
DECOMPOSED = {"autoformer", "fedformer"}


def attention_init(key, d, heads, *, arch):
    p = C.mha_init(key, d, heads)
    if arch == "fedformer":
        k1, k2 = jax.random.split(jax.random.fold_in(key, 7))
        p["freq_wr"] = {"w": jax.random.normal(k1, (64, d), jnp.float32) * 0.02}
        p["freq_wi"] = {"w": jax.random.normal(k2, (64, d), jnp.float32) * 0.02}
    return p
