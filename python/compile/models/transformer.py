"""Encoder–decoder time series forecaster with token merging (table 1 suite).

One parametric model covers the five table-1 architectures through the
attention flavour + decomposition wiring in ``variants.py``:

    arch in {transformer, informer, autoformer, fedformer, nonstationary}

Token merging placement follows §4 "Applying local merging" exactly:

* encoder: local merging with a **global pool** (k = t_l/2) between
  self-attention and the MLP of every layer;
* decoder: **causal** merging (k = 1) between self-attention and
  cross-attention, with a final unmerge (clone-to-neighbours) so the
  projection head sees the full horizon;
* auxiliary per-token tensors (the non-stationary ``delta``) are merged
  with the same correspondences (§C "Applying token merging").

Shapes are fully static: the per-layer token counts come from
``merging.merge_schedule`` so each (arch, L, r) pair is one AOT artifact.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from .. import merging
from . import common as C
from . import variants as V


@dataclass(frozen=True)
class ForecastConfig:
    arch: str = "transformer"
    n_vars: int = 7
    m: int = 192              # input length (paper table 6)
    p: int = 96               # prediction horizon
    label_len: int = 48
    d: int = 64
    heads: int = 8
    enc_layers: int = 2
    dec_layers: int = 1
    mlp_hidden: int = 128
    # token merging
    r_enc: int = 0            # merges per encoder layer
    k_enc: int = 0            # 0 => global pool (k = t_l / 2)
    r_dec: int = 0            # merges per decoder layer (causal, k = 1)
    q_min: int = 4            # minimum remaining tokens (§3)
    metric: str = "cos"
    prune: bool = False       # appendix E.2 baseline: prune instead of merge
    use_pos_embed: bool = True  # appendix E.6 ablation
    probe: str = "none"       # none | tokens (layer-1 reps) | trace (slot maps)

    @property
    def dec_len(self):
        return self.label_len + self.p


def enc_token_counts(cfg: ForecastConfig):
    return merging.merge_schedule(
        cfg.m, r=cfg.r_enc, num_layers=cfg.enc_layers, q=cfg.q_min
    )


def dec_token_counts(cfg: ForecastConfig):
    return merging.merge_schedule(
        cfg.dec_len, r=cfg.r_dec, num_layers=cfg.dec_layers, q=cfg.q_min
    )


# ---------------------------------------------------------------------------
# Init


def init_params(key, cfg: ForecastConfig):
    ks = iter(jax.random.split(key, 16 + 8 * (cfg.enc_layers + cfg.dec_layers)))
    p = {
        "embed_enc": C.dense_init(next(ks), cfg.n_vars, cfg.d),
        "embed_dec": C.dense_init(next(ks), cfg.n_vars, cfg.d),
        "head": C.dense_init(next(ks), cfg.d, cfg.n_vars),
        "enc": [],
        "dec": [],
    }
    for _ in range(cfg.enc_layers):
        p["enc"].append(
            {
                "attn": V.attention_init(next(ks), cfg.d, cfg.heads, arch=cfg.arch),
                "ln1": C.layernorm_init(cfg.d),
                "ln2": C.layernorm_init(cfg.d),
                "mlp": C.mlp_init(next(ks), cfg.d, cfg.mlp_hidden),
            }
        )
    for _ in range(cfg.dec_layers):
        p["dec"].append(
            {
                "self_attn": V.attention_init(next(ks), cfg.d, cfg.heads, arch=cfg.arch),
                "cross_attn": C.mha_init(next(ks), cfg.d, cfg.heads),
                "ln1": C.layernorm_init(cfg.d),
                "ln2": C.layernorm_init(cfg.d),
                "ln3": C.layernorm_init(cfg.d),
                "mlp": C.mlp_init(next(ks), cfg.d, cfg.mlp_hidden),
            }
        )
    if cfg.arch == "nonstationary":
        p["tau_mlp"] = C.dense_init(next(ks), 2 * cfg.n_vars, 1)
        p["delta_mlp"] = C.dense_init(next(ks), cfg.n_vars, 1)
    if cfg.arch in V.DECOMPOSED:
        p["trend_head"] = C.dense_init(next(ks), cfg.n_vars, cfg.n_vars)
    return C.strip_static(p)


# ---------------------------------------------------------------------------
# Merging helpers


def _merge_step(x, sizes, aux, *, r, k, cfg):
    """Merge tokens + auxiliary per-token tensors with shared
    correspondences.  Returns (x, sizes, aux, slot_map)."""
    if r <= 0:
        return x, sizes, aux, jnp.arange(x.shape[0])
    op = merging.prune_fixed_r if cfg.prune else merging.merge_fixed_r
    res = op(x, sizes, r=r, k=k, metric=cfg.metric)
    new_aux = {}
    t_new = res.x.shape[0]
    w = sizes
    den = jax.ops.segment_sum(w, res.slot_map, num_segments=t_new)
    for name, v in aux.items():
        num = jax.ops.segment_sum(v * w, res.slot_map, num_segments=t_new)
        new_aux[name] = num / den
    return res.x, res.sizes, new_aux, res.slot_map


def _attend(p, cfg, xq, xkv, *, bias, tau=None, delta=None):
    if cfg.arch == "nonstationary" and tau is not None:
        return V.destationary_attention(
            p, xq, xkv, heads=cfg.heads, bias=bias, tau=tau, delta=delta
        )
    return V.ATTENTION[cfg.arch](p, xq, xkv, heads=cfg.heads, bias=bias)


# ---------------------------------------------------------------------------
# Forward


def forward(params, x, cfg: ForecastConfig):
    """x: (m, n_vars) -> forecast (p, n_vars) [+ probes]."""
    m, n = x.shape
    assert (m, n) == (cfg.m, cfg.n_vars)

    # --- non-stationary stationarization -----------------------------------
    tau = delta_raw = None
    if cfg.arch == "nonstationary":
        mu = jnp.mean(x, 0, keepdims=True)
        sigma = jnp.std(x, 0, keepdims=True) + 1e-5
        x = (x - mu) / sigma
        stats = jnp.concatenate([mu[0], sigma[0]])
        tau = jnp.exp(C.dense(params["tau_mlp"], stats))[0]
        # per-token delta from the raw-ish tokens (merged alongside below)
        delta_raw = C.dense(params["delta_mlp"], x)[:, 0]      # (m,)

    # --- encoder ------------------------------------------------------------
    h = C.dense(params["embed_enc"], x)
    if cfg.use_pos_embed:
        h = h + C.sinusoidal_pe(cfg.m, cfg.d)
    sizes = jnp.ones((cfg.m,), jnp.float32)
    aux = {} if delta_raw is None else {"delta": delta_raw}
    counts = enc_token_counts(cfg)
    probes = {}
    enc_maps = []
    for li, lp in enumerate(params["enc"]):
        t_l = h.shape[0]
        bias = C.size_bias(sizes, t_l)
        d_l = aux.get("delta")
        ha = _attend(lp["attn"], cfg, C.layernorm(lp["ln1"], h), C.layernorm(lp["ln1"], h),
                     bias=bias, tau=tau, delta=d_l)
        h = h + ha
        if cfg.arch in V.DECOMPOSED:
            h, _ = C.series_decomp(h)
        if li == 0 and cfg.probe == "tokens":
            probes["tokens_l1"] = h
        r_l = counts[li] - counts[li + 1]
        k_l = cfg.k_enc if cfg.k_enc > 0 else max(1, h.shape[0] // 2)
        h, sizes, aux, smap = _merge_step(h, sizes, aux, r=r_l, k=k_l, cfg=cfg)
        enc_maps.append(smap)
        h = h + C.mlp(lp["mlp"], C.layernorm(lp["ln2"], h))
        if cfg.arch in V.DECOMPOSED:
            h, _ = C.series_decomp(h)
    enc_out, enc_sizes = h, sizes

    # --- decoder ------------------------------------------------------------
    x_dec = jnp.concatenate(
        [x[cfg.m - cfg.label_len:], jnp.zeros((cfg.p, n), x.dtype)], 0
    )
    g = C.dense(params["embed_dec"], x_dec)
    if cfg.use_pos_embed:
        g = g + C.sinusoidal_pe(cfg.dec_len, cfg.d)
    dsizes = jnp.ones((cfg.dec_len,), jnp.float32)
    dcounts = dec_token_counts(cfg)
    dec_maps = []
    trend_acc = jnp.zeros((cfg.dec_len, n), jnp.float32)
    for li, lp in enumerate(params["dec"]):
        t_l = g.shape[0]
        bias = C.causal_mask(t_l) + C.size_bias(dsizes, t_l)
        ga = C.mha(lp["self_attn"], C.layernorm(lp["ln1"], g),
                   C.layernorm(lp["ln1"], g), heads=cfg.heads, bias=bias)
        g = g + ga
        r_l = dcounts[li] - dcounts[li + 1]
        g, dsizes, _, smap = _merge_step(g, dsizes, {}, r=r_l, k=1, cfg=cfg)
        dec_maps.append(smap)
        cbias = C.size_bias(enc_sizes, g.shape[0])
        g = g + C.mha(lp["cross_attn"], C.layernorm(lp["ln2"], g), enc_out,
                      heads=cfg.heads, bias=cbias)
        g = g + C.mlp(lp["mlp"], C.layernorm(lp["ln3"], g))
        if cfg.arch in V.DECOMPOSED:
            g, tr = C.series_decomp(g)
            trend_acc = trend_acc + merging.unmerge(
                C.dense(params["head"], tr), merging.compose_slot_maps(dec_maps)
            )

    # --- unmerge + head ------------------------------------------------------
    if dec_maps:
        g = merging.unmerge(g, merging.compose_slot_maps(dec_maps))
    y = C.dense(params["head"], g)
    if cfg.arch in V.DECOMPOSED:
        y = y + trend_acc
    y = y[-cfg.p:]
    if cfg.arch == "nonstationary":
        y = y * sigma + mu

    if cfg.probe == "tokens":
        return y, probes["tokens_l1"]
    if cfg.probe == "trace":
        return y, merging.compose_slot_maps(enc_maps)
    return y


def forward_batch(params, xb, cfg: ForecastConfig):
    """(batch, m, n) -> (batch, p, n) — the AOT entrypoint."""
    return jax.vmap(lambda x: forward(params, x, cfg))(xb)
