"""Mamba state-space classifier for the genomic experiment (§5.4, table 3).

Mamba block (Gu & Dao 2023): in-projection to (x, z) streams; short causal
depthwise conv + SiLU on x; selective SSM with input-dependent (dt, B, C)
through the Layer-1 Pallas ``selective_scan`` kernel; gated by SiLU(z);
out-projection.  Token merging after the operator, ``k = 1`` (§4) with the
global pool exposed for table 3.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import merging
from ..kernels import dispatch as ssm_kernel
from . import common as C


@dataclass(frozen=True)
class MambaConfig:
    vocab: int = 5
    m: int = 1024
    n_classes: int = 2
    d: int = 64
    d_inner: int = 128        # expansion factor 2
    d_state: int = 8
    d_conv: int = 4
    layers: int = 4
    r: int = 0
    k: int = 1
    q_min: int = 16
    metric: str = "cos"


def init_params(key, cfg: MambaConfig):
    ks = iter(jax.random.split(key, 4 + 8 * cfg.layers))
    p = {
        "embed": C.embedding_init(next(ks), cfg.vocab, cfg.d),
        "head": C.dense_init(next(ks), cfg.d, cfg.n_classes),
        "blocks": [],
    }
    for _ in range(cfg.layers):
        di, n = cfg.d_inner, cfg.d_state
        p["blocks"].append(
            {
                "in_proj": C.dense_init(next(ks), cfg.d, 2 * di),
                "conv_w": jax.random.normal(next(ks), (cfg.d_conv, di), jnp.float32)
                * 0.2,
                "conv_b": jnp.zeros((di,), jnp.float32),
                "x_proj": C.dense_init(next(ks), di, 2 * n + 1),
                "dt_proj": C.dense_init(next(ks), 1, di),
                # A initialised to -[1..n] per channel (S4D-real)
                "a_log": jnp.log(
                    jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
                ),
                "dcoef": jnp.ones((di,), jnp.float32),
                "out_proj": C.dense_init(next(ks), di, cfg.d),
                "ln": C.layernorm_init(cfg.d),
            }
        )
    return C.strip_static(p)


def _causal_depthwise_conv(x, w, b):
    """x (t, di), w (kw, di) -> causal depthwise conv (t, di)."""
    kw = w.shape[0]
    xp = jnp.concatenate([jnp.zeros((kw - 1, x.shape[1]), x.dtype), x], 0)
    out = jnp.zeros_like(x)
    for i in range(kw):
        out = out + xp[i : i + x.shape[0]] * w[i]
    return out + b


def mamba_operator(bp, x, cfg: MambaConfig):
    t = x.shape[0]
    xz = C.dense(bp["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                       # (t, di) each
    xi = jax.nn.silu(_causal_depthwise_conv(xi, bp["conv_w"], bp["conv_b"]))
    n = cfg.d_state
    proj = C.dense(bp["x_proj"], xi)                        # (t, 2n+1)
    b, c, dt_in = proj[:, :n], proj[:, n : 2 * n], proj[:, 2 * n :]
    dt = jax.nn.softplus(C.dense(bp["dt_proj"], dt_in))     # (t, di)
    a = -jnp.exp(bp["a_log"])                               # (di, n)
    y = ssm_kernel.selective_scan(xi, dt, a, b, c, bp["dcoef"])
    y = y * jax.nn.silu(z)
    return C.dense(bp["out_proj"], y)


def forward(params, ids, cfg: MambaConfig):
    """ids: (m,) int32 -> logits (n_classes,)."""
    h = params["embed"]["e"][ids]
    sizes = jnp.ones((cfg.m,), jnp.float32)
    counts = merging.merge_schedule(cfg.m, r=cfg.r, num_layers=cfg.layers,
                                    q=cfg.q_min)
    for li, bp in enumerate(params["blocks"]):
        h = h + mamba_operator(bp, C.layernorm(bp["ln"], h), cfg)
        r_l = counts[li] - counts[li + 1]
        if r_l > 0:
            k_l = cfg.k if cfg.k > 0 else max(1, h.shape[0] // 2)
            res = merging.merge_fixed_r(h, sizes, r=r_l, k=k_l, metric=cfg.metric)
            h, sizes = res.x, res.sizes
    pooled = jnp.sum(h * sizes[:, None], 0) / jnp.sum(sizes)
    return C.dense(params["head"], pooled)


def forward_batch(params, idsb, cfg: MambaConfig):
    return jax.vmap(lambda i: forward(params, i, cfg))(idsb)
