"""Chronos-like univariate quantized-vocabulary forecaster (§5.3 suite).

The Chronos signature (Ansari et al. 2024) is its tokenizer: mean-scale the
context, clip, quantize into a fixed uniform vocabulary, and model token
ids with an encoder–decoder transformer.  We reproduce that design at
tractable scale (DESIGN.md §7): sizes S/M/L instead of tiny…large, and a
teacher-forced p-step decoder head instead of autoregressive sampling (the
merging mechanics — encoder global-pool merging + decoder causal merging +
unmerge — are identical).

Forward: context (m,) float -> (logits (p, vocab), scale ()).  Rust
dequantizes argmax ids through the bin centres * scale (eval/serving), and
cross-entropy trains against quantized targets.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import merging
from . import common as C


@dataclass(frozen=True)
class ChronosConfig:
    m: int = 512              # context length (paper default)
    p: int = 64               # prediction horizon (paper default)
    vocab: int = 256
    clip: float = 15.0        # scaled-value clipping range
    d: int = 64
    heads: int = 4
    enc_layers: int = 4
    dec_layers: int = 1
    mlp_hidden: int = 128
    r_enc: int = 0
    k_enc: int = 0            # 0 => global pool
    r_dec: int = 0
    q_min: int = 8
    metric: str = "cos"
    prune: bool = False
    use_pos_embed: bool = True
    probe: str = "none"       # none | tokens | trace


SIZES = {
    "s": dict(d=64, heads=4, enc_layers=2, mlp_hidden=128),
    "m": dict(d=96, heads=6, enc_layers=4, mlp_hidden=192),
    "l": dict(d=128, heads=8, enc_layers=6, mlp_hidden=256),
}


def tokenize(x, cfg: ChronosConfig):
    """Mean-scaling + uniform-bin quantization (the Chronos tokenizer)."""
    scale = jnp.mean(jnp.abs(x)) + 1e-6
    xs = jnp.clip(x / scale, -cfg.clip, cfg.clip)
    ids = jnp.round((xs + cfg.clip) / (2 * cfg.clip) * (cfg.vocab - 1))
    return ids.astype(jnp.int32), scale


def bin_centers(cfg: ChronosConfig):
    return (jnp.arange(cfg.vocab) / (cfg.vocab - 1)) * 2 * cfg.clip - cfg.clip


def init_params(key, cfg: ChronosConfig):
    ks = iter(jax.random.split(key, 8 + 4 * (cfg.enc_layers + cfg.dec_layers)))
    p = {
        "embed": C.embedding_init(next(ks), cfg.vocab, cfg.d),
        "dec_query": jax.random.normal(next(ks), (cfg.p, cfg.d), jnp.float32) * 0.02,
        "head": C.dense_init(next(ks), cfg.d, cfg.vocab),
        "enc": [],
        "dec": [],
    }
    for _ in range(cfg.enc_layers):
        p["enc"].append(
            {
                "attn": C.mha_init(next(ks), cfg.d, cfg.heads),
                "ln1": C.layernorm_init(cfg.d),
                "ln2": C.layernorm_init(cfg.d),
                "mlp": C.mlp_init(next(ks), cfg.d, cfg.mlp_hidden),
            }
        )
    for _ in range(cfg.dec_layers):
        p["dec"].append(
            {
                "self_attn": C.mha_init(next(ks), cfg.d, cfg.heads),
                "cross_attn": C.mha_init(next(ks), cfg.d, cfg.heads),
                "ln1": C.layernorm_init(cfg.d),
                "ln2": C.layernorm_init(cfg.d),
                "ln3": C.layernorm_init(cfg.d),
                "mlp": C.mlp_init(next(ks), cfg.d, cfg.mlp_hidden),
            }
        )
    return C.strip_static(p)


def forward(params, x, cfg: ChronosConfig):
    """x: (m,) float context -> (logits (p, vocab), scale)."""
    ids, scale = tokenize(x, cfg)
    h = params["embed"]["e"][ids]
    if cfg.use_pos_embed:
        h = h + C.sinusoidal_pe(cfg.m, cfg.d)
    sizes = jnp.ones((cfg.m,), jnp.float32)
    counts = merging.merge_schedule(cfg.m, r=cfg.r_enc, num_layers=cfg.enc_layers,
                                    q=cfg.q_min)
    probes = {}
    enc_maps = []
    op = merging.prune_fixed_r if cfg.prune else merging.merge_fixed_r
    for li, lp in enumerate(params["enc"]):
        t_l = h.shape[0]
        bias = C.size_bias(sizes, t_l)
        h = h + C.mha(lp["attn"], C.layernorm(lp["ln1"], h),
                      C.layernorm(lp["ln1"], h), heads=cfg.heads, bias=bias)
        if li == 0 and cfg.probe == "tokens":
            probes["tokens_l1"] = h
        r_l = counts[li] - counts[li + 1]
        if r_l > 0:
            k_l = cfg.k_enc if cfg.k_enc > 0 else max(1, h.shape[0] // 2)
            res = op(h, sizes, r=r_l, k=k_l, metric=cfg.metric)
            h, sizes = res.x, res.sizes
            enc_maps.append(res.slot_map)
        else:
            enc_maps.append(jnp.arange(h.shape[0]))
        h = h + C.mlp(lp["mlp"], C.layernorm(lp["ln2"], h))
    enc_out, enc_sizes = h, sizes

    g = params["dec_query"] + C.sinusoidal_pe(cfg.p, cfg.d)
    dsizes = jnp.ones((cfg.p,), jnp.float32)
    dcounts = merging.merge_schedule(cfg.p, r=cfg.r_dec, num_layers=cfg.dec_layers,
                                     q=cfg.q_min)
    dec_maps = []
    for li, lp in enumerate(params["dec"]):
        t_l = g.shape[0]
        bias = C.causal_mask(t_l) + C.size_bias(dsizes, t_l)
        g = g + C.mha(lp["self_attn"], C.layernorm(lp["ln1"], g),
                      C.layernorm(lp["ln1"], g), heads=cfg.heads, bias=bias)
        r_l = dcounts[li] - dcounts[li + 1]
        if r_l > 0:
            res = merging.merge_causal(g, dsizes, r=r_l, metric=cfg.metric)
            g, dsizes = res.x, res.sizes
            dec_maps.append(res.slot_map)
        cbias = C.size_bias(enc_sizes, g.shape[0])
        g = g + C.mha(lp["cross_attn"], C.layernorm(lp["ln2"], g), enc_out,
                      heads=cfg.heads, bias=cbias)
        g = g + C.mlp(lp["mlp"], C.layernorm(lp["ln3"], g))
    if dec_maps:
        g = merging.unmerge(g, merging.compose_slot_maps(dec_maps))
    logits = C.dense(params["head"], g)

    if cfg.probe == "tokens":
        return logits, scale, probes["tokens_l1"]
    if cfg.probe == "trace":
        return logits, scale, merging.compose_slot_maps(enc_maps)
    return logits, scale


def forward_batch(params, xb, cfg: ChronosConfig):
    return jax.vmap(lambda x: forward(params, x, cfg))(xb)


def forward_dynamic(params, x, threshold, cfg: ChronosConfig):
    """Dynamic token merging (§5.5): the merge decision is made *inside*
    the graph from a cosine-similarity ``threshold`` passed as a runtime
    input, so one artifact serves every threshold.  Shapes stay static via
    the masked-merge formulation (DESIGN.md §3); the summed effective token
    count drives the FLOPs model (fig. 4)."""
    ids, scale = tokenize(x, cfg)
    h = params["embed"]["e"][ids]
    if cfg.use_pos_embed:
        h = h + C.sinusoidal_pe(cfg.m, cfg.d)
    sizes = jnp.ones((cfg.m,), jnp.float32)
    eff_total = jnp.zeros((), jnp.int32)
    for lp in params["enc"]:
        bias = C.size_bias(sizes, h.shape[0])
        h = h + C.mha(lp["attn"], C.layernorm(lp["ln1"], h),
                      C.layernorm(lp["ln1"], h), heads=cfg.heads, bias=bias)
        h, eff = merging.dynamic_mask_merge(h, threshold=threshold, k=1,
                                            metric=cfg.metric)
        eff_total = eff_total + eff
        h = h + C.mlp(lp["mlp"], C.layernorm(lp["ln2"], h))
    enc_out, enc_sizes = h, sizes

    g = params["dec_query"] + C.sinusoidal_pe(cfg.p, cfg.d)
    dsizes = jnp.ones((cfg.p,), jnp.float32)
    for lp in params["dec"]:
        bias = C.causal_mask(g.shape[0]) + C.size_bias(dsizes, g.shape[0])
        g = g + C.mha(lp["self_attn"], C.layernorm(lp["ln1"], g),
                      C.layernorm(lp["ln1"], g), heads=cfg.heads, bias=bias)
        cbias = C.size_bias(enc_sizes, g.shape[0])
        g = g + C.mha(lp["cross_attn"], C.layernorm(lp["ln2"], g), enc_out,
                      heads=cfg.heads, bias=cbias)
        g = g + C.mlp(lp["mlp"], C.layernorm(lp["ln3"], g))
    logits = C.dense(params["head"], g)
    return logits, scale, eff_total


def forward_dynamic_batch(params, xb, threshold, cfg: ChronosConfig):
    return jax.vmap(lambda x: forward_dynamic(params, x, threshold, cfg))(xb)


def dequantize(logits, scale, cfg: ChronosConfig):
    """Greedy decode to values — mirrored in Rust eval; kept here for tests."""
    ids = jnp.argmax(logits, -1)
    return bin_centers(cfg)[ids] * scale
