"""Shared Layer-2 building blocks: initialisers, layers, attention wiring.

Every model is a pure function ``f(params, *inputs)`` over a nested dict of
arrays so that weights are **runtime inputs** of the lowered HLO — Rust owns
the weights (init / train / serve); Python never runs after ``make
artifacts``.

Attention dispatches to the L1 Pallas ``fused_attention`` kernel and
implements ToMe *proportional attention*: tokens carry sizes and keys get an
additive ``log size`` bias so a merged token attends like the originals it
represents (Bolya et al. 2023, adopted by the paper).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..kernels import dispatch as attn_kernel

# ---------------------------------------------------------------------------
# Initialisation


def dense_init(key, d_in, d_out):
    wk, _ = jax.random.split(key)
    scale = math.sqrt(2.0 / (d_in + d_out))
    return {
        "w": jax.random.normal(wk, (d_in, d_out), jnp.float32) * scale,
        "b": jnp.zeros((d_out,), jnp.float32),
    }


def layernorm_init(d):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def embedding_init(key, vocab, d):
    return {"e": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def mha_init(key, d, heads):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, d),
        "wk": dense_init(ks[1], d, d),
        "wv": dense_init(ks[2], d, d),
        "wo": dense_init(ks[3], d, d),
        "heads": heads,  # static; stripped before lowering
    }


def mlp_init(key, d, hidden):
    k1, k2 = jax.random.split(key)
    return {"fc1": dense_init(k1, d, hidden), "fc2": dense_init(k2, hidden, d)}


def strip_static(params):
    """Remove non-array static entries (e.g. ``heads``) before lowering."""
    if isinstance(params, dict):
        return {
            k: strip_static(v)
            for k, v in params.items()
            if not isinstance(v, (int, float, str, bool))
        }
    if isinstance(params, (list, tuple)):
        return type(params)(strip_static(v) for v in params)
    return params


# ---------------------------------------------------------------------------
# Layers


def dense(p, x):
    return x @ p["w"] + p["b"]


def layernorm(p, x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def mlp(p, x):
    return dense(p["fc2"], jax.nn.gelu(dense(p["fc1"], x)))


def split_heads(x, heads):
    t, d = x.shape
    return x.reshape(t, heads, d // heads).transpose(1, 0, 2)  # (h, t, dh)


def join_heads(x):
    h, t, dh = x.shape
    return x.transpose(1, 0, 2).reshape(t, h * dh)


def causal_mask(t):
    return jnp.where(jnp.tril(jnp.ones((t, t), bool)), 0.0, -1e9).astype(jnp.float32)


def size_bias(sizes, tq):
    """Proportional-attention additive bias, broadcast to (tq, tk)."""
    return jnp.broadcast_to(jnp.log(sizes)[None, :], (tq, sizes.shape[0]))


def mha(p, xq, xkv, *, heads, bias):
    """Multi-head attention via the Pallas kernel.

    xq: (tq, d), xkv: (tk, d), bias: (tq, tk) additive (mask + log-sizes).
    The kernel requires tq == tk blocks; for cross attention with tq != tk
    we fall back to the jnp formulation (identical math, checked by ref).
    """
    q = split_heads(dense(p["wq"], xq), heads)
    k = split_heads(dense(p["wk"], xkv), heads)
    v = split_heads(dense(p["wv"], xkv), heads)
    tq, tk = xq.shape[0], xkv.shape[0]
    if tq == tk:
        o = attn_kernel.fused_attention(q, k, v, bias)
    else:
        dh = q.shape[-1]
        logits = jnp.einsum("htd,hsd->hts", q, k) / math.sqrt(dh) + bias[None]
        o = jnp.einsum("hts,hsd->htd", jax.nn.softmax(logits, -1), v)
    return dense(p["wo"], join_heads(o))


def sinusoidal_pe(t, d):
    pos = jnp.arange(t)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, 2.0 * i / d)
    pe = jnp.zeros((t, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


def moving_avg(x, win):
    """Series-decomposition trend extractor (Autoformer/FEDformer):
    edge-replicated moving average along the token axis."""
    t = x.shape[0]
    pad_l = (win - 1) // 2
    pad_r = win - 1 - pad_l
    xp = jnp.concatenate(
        [jnp.repeat(x[:1], pad_l, 0), x, jnp.repeat(x[-1:], pad_r, 0)], 0
    )
    cs = jnp.cumsum(jnp.concatenate([jnp.zeros_like(xp[:1]), xp], 0), 0)
    return (cs[win:] - cs[:-win]) / win


def series_decomp(x, win=25):
    trend = moving_avg(x, win)
    return x - trend, trend
