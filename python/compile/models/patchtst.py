"""PatchTST forecaster (appendix E.3, table 8): patch tokenization.

Channel-independent: each variate's series (m,) is split into overlapping
patches which are embedded as tokens (Nie et al. 2023); a shared vanilla
encoder with token merging processes the ~24-token sequence; a flatten +
linear head predicts the horizon.  Demonstrates that local merging works on
top of the patch token type (paper: "the tokenization method is of minor
importance for token merging").
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import merging
from . import common as C


@dataclass(frozen=True)
class PatchTSTConfig:
    n_vars: int = 7
    m: int = 192
    p: int = 96
    patch_len: int = 16
    stride: int = 8
    d: int = 64
    heads: int = 8
    layers: int = 2
    mlp_hidden: int = 128
    r: int = 0                # merges per layer
    k: int = 0                # 0 => global pool
    q_min: int = 4
    metric: str = "cos"

    @property
    def n_patches(self):
        return (self.m - self.patch_len) // self.stride + 1


def init_params(key, cfg: PatchTSTConfig):
    ks = iter(jax.random.split(key, 4 + 4 * cfg.layers))
    p = {
        "embed": C.dense_init(next(ks), cfg.patch_len, cfg.d),
        "head": C.dense_init(next(ks), cfg.n_patches * cfg.d, cfg.p),
        "enc": [],
    }
    for _ in range(cfg.layers):
        p["enc"].append(
            {
                "attn": C.mha_init(next(ks), cfg.d, cfg.heads),
                "ln1": C.layernorm_init(cfg.d),
                "ln2": C.layernorm_init(cfg.d),
                "mlp": C.mlp_init(next(ks), cfg.d, cfg.mlp_hidden),
            }
        )
    return C.strip_static(p)


def _patch(series, cfg: PatchTSTConfig):
    idx = jnp.arange(cfg.n_patches)[:, None] * cfg.stride + jnp.arange(cfg.patch_len)
    return series[idx]                                       # (n_patches, patch_len)


def _encode_channel(params, series, cfg: PatchTSTConfig):
    h = C.dense(params["embed"], _patch(series, cfg))
    h = h + C.sinusoidal_pe(cfg.n_patches, cfg.d)
    sizes = jnp.ones((cfg.n_patches,), jnp.float32)
    counts = merging.merge_schedule(cfg.n_patches, r=cfg.r, num_layers=cfg.layers,
                                    q=cfg.q_min)
    slot_maps = []
    for li, lp in enumerate(params["enc"]):
        t_l = h.shape[0]
        bias = C.size_bias(sizes, t_l)
        h = h + C.mha(lp["attn"], C.layernorm(lp["ln1"], h),
                      C.layernorm(lp["ln1"], h), heads=cfg.heads, bias=bias)
        r_l = counts[li] - counts[li + 1]
        if r_l > 0:
            k_l = cfg.k if cfg.k > 0 else max(1, h.shape[0] // 2)
            res = merging.merge_fixed_r(h, sizes, r=r_l, k=k_l, metric=cfg.metric)
            h, sizes = res.x, res.sizes
            slot_maps.append(res.slot_map)
        h = h + C.mlp(lp["mlp"], C.layernorm(lp["ln2"], h))
    # Unmerge to the full patch count so the flatten head is size-stable.
    if slot_maps:
        h = merging.unmerge(h, merging.compose_slot_maps(slot_maps))
    return h.reshape(-1)


def forward(params, x, cfg: PatchTSTConfig):
    """x: (m, n_vars) -> (p, n_vars), channel-independent shared weights.

    Per-instance normalization (RevIN-style) as in PatchTST.
    """
    mu = jnp.mean(x, 0, keepdims=True)
    sigma = jnp.std(x, 0, keepdims=True) + 1e-5
    xs = ((x - mu) / sigma).T                                # (n_vars, m)
    flat = jax.vmap(lambda s: _encode_channel(params, s, cfg))(xs)
    y = jax.vmap(lambda f: C.dense(params["head"], f))(flat) # (n_vars, p)
    return y.T * sigma + mu


def forward_batch(params, xb, cfg: PatchTSTConfig):
    return jax.vmap(lambda x: forward(params, x, cfg))(xb)
