"""On-disk interchange formats shared with the Rust runtime.

* **weights.bin** ("safetensors-lite"): ``u64 LE header length | JSON
  header | raw tensor data``.  Header maps tensor name -> {dtype, shape,
  data_offsets: [start, end]} with offsets relative to the data section.
  Rust mirrors this in ``rust/src/runtime/weights.rs``.

* **manifest.json** (one per HLO artifact): the exact flattened HLO
  parameter order — params first (tree-flatten order of the nested dict,
  names joined with '/'), then data inputs — plus output specs and
  experiment metadata (token counts per layer etc.).  The Rust runtime
  validates shapes against it and binds weights by name.
"""

from __future__ import annotations

import json
import struct

import jax
import numpy as np

DTYPES = {"float32": "f32", "int32": "i32"}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def flatten_named(tree):
    """[(name, array)] in exactly the order jax.jit flattens arguments."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_path_str(path), np.asarray(leaf)) for path, leaf in leaves]


def write_weights(path, tree):
    named = flatten_named(tree)
    header, offset = {}, 0
    blobs = []
    for name, arr in named:
        # note: ascontiguousarray would promote 0-d scalars to (1,)
        arr = np.asarray(arr, order="C")
        blob = arr.tobytes()
        header[name] = {
            "dtype": DTYPES[str(arr.dtype)],
            "shape": list(arr.shape),
            "data_offsets": [offset, offset + len(blob)],
        }
        blobs.append(blob)
        offset += len(blob)
    hjson = json.dumps(header, sort_keys=True).encode()
    with open(path, "wb") as f:
        f.write(struct.pack("<Q", len(hjson)))
        f.write(hjson)
        for b in blobs:
            f.write(b)


def read_weights(path):
    with open(path, "rb") as f:
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen))
        data = f.read()
    out = {}
    for name, spec in header.items():
        s, e = spec["data_offsets"]
        dt = {"f32": np.float32, "i32": np.int32}[spec["dtype"]]
        out[name] = np.frombuffer(data[s:e], dtype=dt).reshape(spec["shape"])
    return out


def tensor_spec(name, arr_or_spec):
    shape = list(arr_or_spec.shape)
    dtype = DTYPES.get(str(arr_or_spec.dtype), str(arr_or_spec.dtype))
    return {"name": name, "shape": shape, "dtype": dtype}


def write_manifest(path, *, name, family, config, params_tree, inputs, outputs,
                   meta=None, merge_spec=None):
    manifest = {
        "name": name,
        "family": family,
        "config": config,
        "params": [tensor_spec(n, a) for n, a in flatten_named(params_tree)],
        "inputs": [tensor_spec(n, s) for n, s in inputs],
        "outputs": [tensor_spec(n, s) for n, s in outputs],
        "meta": meta or {},
    }
    if merge_spec is not None:
        # Same JSON dialect as the Rust loader's "merge" block
        # (config::merge_spec_from_json) — the serving coordinator prefers
        # this over its own config when the manifest carries one.
        manifest["merge_spec"] = merge_spec
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
