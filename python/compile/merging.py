"""Layer-2 token merging ops (paper §3) — static-shape, AOT-compatible.

Implements, on top of the L1 Pallas similarity kernels:

* ``merge_fixed_r``  — global / local(k) bipartite soft matching with a
  fixed merge count ``r`` (static output shape ``t - r``), order- and
  causality-preserving, with ToMe token-size tracking.
* ``merge_causal``   — the ``k = 1`` special case used in decoders.
* ``prune_fixed_r``  — the pruning baseline of appendix E.2 (drop instead
  of average).
* ``unmerge``        — clone-to-neighbours reconstruction (paper §3
  "Causal token merging for decoders"): a gather by the slot map.
* ``dynamic_mask_merge`` — threshold-based dynamic merging (§5.5) realised
  as an in-place masked average so shapes stay static; emits the effective
  token count for the FLOPs model (fig. 4).

Conventions: tokens ``x`` are ``(t, d)``; ``sizes`` ``(t,)`` counts how
many original tokens each current token represents.  Subsets A/B are the
even/odd positions (alternation, §3).  When ``t`` is odd the most recent
token is excluded from merging (§3, Markov argument).

The merged representation of a pair lands at the *later* of the two source
positions, so with ``k = 1`` information only ever flows forward in time —
this is what makes the scheme causal and decoder-safe.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import dispatch as local_merge

NEG_INF = -1e9



def rank_desc(x):
    """Descending rank (0 = largest) along the last axis, computed by
    comparison counting instead of ``argsort``: the sort primitive's
    transpose emits batched gathers under ``vmap``+``grad`` that the
    xla_extension 0.5.1 converter rejects.  Ties break by position
    (earlier index ranks higher), so ``rank < r`` selects exactly r."""
    xi = x[..., :, None]
    xj = x[..., None, :]
    i = jnp.arange(x.shape[-1])[:, None]
    j = jnp.arange(x.shape[-1])[None, :]
    greater = (xj > xi) | ((xj == xi) & (j < i))
    return jnp.sum(greater.astype(jnp.int32), axis=-1)

def topk_desc(x, k):
    """Sort-based descending top-k along the last axis.

    ``jax.lax.top_k`` lowers to a ``topk`` HLO instruction whose text form
    xla_extension 0.5.1 cannot parse; ``argsort`` lowers to plain ``sort``
    which round-trips fine.  Semantics match ``lax.top_k`` (values, indices).
    """
    idx = jnp.argsort(-x, axis=-1)[..., :k]
    return jnp.take_along_axis(x, idx, axis=-1), idx



class MergeResult(NamedTuple):
    """Output of a merge step.

    x:       (t - r, d) merged tokens, original temporal order preserved.
    sizes:   (t - r,)  token sizes (for proportional attention / averaging).
    slot_map:(t,)      original position -> output slot; ``unmerge`` gathers
                       through it, and chaining slot_maps across layers
                       yields the merge trace of fig. 8.
    """

    x: jnp.ndarray
    sizes: jnp.ndarray
    slot_map: jnp.ndarray


def _banded_similarity_metric(a, b, *, k, metric):
    """(t2, 2k-1) banded similarity under the requested metric.

    ``cos`` dispatches to the L1 Pallas kernel; ``l1``/``l2`` (appendix
    E.1 ablation) use negative distances computed densely in jnp — they
    are ablation-only and never on a hot path.
    """
    if metric == "cos":
        return local_merge.similarity(a, b, k=k) if k >= a.shape[0] else \
            local_merge.banded_similarity(a, b, k=k)
    t2 = a.shape[0]
    diff = a[:, None, :] - b[None, :, :]
    if metric == "l1":
        s = -jnp.sum(jnp.abs(diff), axis=-1)
    elif metric == "l2":
        s = -jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-12)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    i = jnp.arange(t2)[:, None]
    p = jnp.arange(2 * k - 1)[None, :]
    j = i + p - (k - 1)
    valid = (j >= 0) & (j < t2)
    return jnp.where(valid, s[i, jnp.clip(j, 0, t2 - 1)], NEG_INF)


def _match(x, *, k, metric):
    """Bipartite soft matching on the A/B split.

    Returns (node_max, best_j) over the ``t2`` A-tokens: the best match
    score and the matched B index for every A token.
    """
    te = x.shape[0] - (x.shape[0] % 2)
    a = x[0:te:2]
    b = x[1:te:2]
    t2 = te // 2
    k = min(k, t2)
    if k >= t2:
        s = local_merge.full_similarity(a, b) if metric == "cos" else \
            _banded_similarity_metric(a, b, k=t2, metric=metric)
        if s.shape[1] == 2 * t2 - 1:  # banded layout at k == t2
            best_p = jnp.argmax(s, axis=-1)
            node_max = jnp.max(s, axis=-1)
            best_j = jnp.arange(t2) + best_p - (t2 - 1)
            return node_max, best_j
        best_j = jnp.argmax(s, axis=-1)
        node_max = jnp.max(s, axis=-1)
        return node_max, best_j
    s = _banded_similarity_metric(a, b, k=k, metric=metric)
    best_p = jnp.argmax(s, axis=-1)
    node_max = jnp.max(s, axis=-1)
    best_j = jnp.arange(t2) + best_p - (k - 1)
    return node_max, jnp.clip(best_j, 0, t2 - 1)


def merge_fixed_r(x, sizes, *, r, k, metric="cos"):
    """Merge the ``r`` most similar A-tokens into their matched B-tokens.

    Order-preserving, size-weighted averaging, static output length
    ``t - r``.  ``k`` is the locality constraint of eq. 1 (``k >= t//2``
    gives the global pool).
    """
    t, _ = x.shape
    if r <= 0:
        return MergeResult(x, sizes, jnp.arange(t))
    te = t - (t % 2)
    t2 = te // 2
    assert 0 < r <= t2, f"r={r} out of range for t={t}"

    node_max, best_j = _match(x, k=k, metric=metric)
    # Top-r A tokens by best-match score are merged away.  The mask comes
    # from a rank computation (argsort of argsort) rather than an index
    # scatter: scatters acquire batching dims under vmap+grad that the
    # xla_extension 0.5.1 converter rejects, and rank < r selects exactly
    # r tokens even under ties.
    merged_mask_a = rank_desc(node_max) < r

    pos = jnp.arange(t)
    is_a = (pos % 2 == 0) & (pos < te)
    a_idx = pos // 2
    merged = is_a & merged_mask_a[jnp.clip(a_idx, 0, t2 - 1)]

    kept = ~merged
    # Output slot of every kept token, in temporal order.
    slot_of_kept = jnp.cumsum(kept.astype(jnp.int32)) - 1
    # Destination of a merged A token: the slot of its matched B token
    # (original position 2*best_j + 1).
    partner_pos = 2 * best_j + 1
    partner_slot = slot_of_kept[partner_pos]                    # (t2,)
    slot_map = jnp.where(
        merged, partner_slot[jnp.clip(a_idx, 0, t2 - 1)], slot_of_kept
    )

    w = sizes.astype(jnp.float32)
    num = jax.ops.segment_sum(x * w[:, None], slot_map, num_segments=t - r)
    den = jax.ops.segment_sum(w, slot_map, num_segments=t - r)
    out = num / den[:, None]
    return MergeResult(out, den, slot_map)


def merge_causal(x, sizes, *, r, metric="cos"):
    """Causal merging for decoders: the ``k = 1`` special case (§3)."""
    return merge_fixed_r(x, sizes, r=r, k=1, metric=metric)


def prune_fixed_r(x, sizes, *, r, k, metric="cos"):
    """Pruning baseline (appendix E.2): drop the ``r`` most redundant
    A-tokens instead of averaging them into their match."""
    t, _ = x.shape
    if r <= 0:
        return MergeResult(x, sizes, jnp.arange(t))
    te = t - (t % 2)
    t2 = te // 2
    node_max, best_j = _match(x, k=k, metric=metric)
    pruned_mask_a = rank_desc(node_max) < r
    pos = jnp.arange(t)
    is_a = (pos % 2 == 0) & (pos < te)
    a_idx = pos // 2
    pruned = is_a & pruned_mask_a[jnp.clip(a_idx, 0, t2 - 1)]
    kept = ~pruned
    slot_of_kept = jnp.cumsum(kept.astype(jnp.int32)) - 1
    partner_slot = slot_of_kept[2 * best_j + 1]
    slot_map = jnp.where(pruned, partner_slot[jnp.clip(a_idx, 0, t2 - 1)],
                         slot_of_kept)
    # Gather (not average): kept tokens pass through unchanged.
    order = jnp.argsort(jnp.where(kept, slot_of_kept, t))
    out = x[order[: t - r]]
    out_sizes = sizes[order[: t - r]]
    return MergeResult(out, out_sizes, slot_map)


def unmerge(y, slot_map):
    """Clone-to-neighbours unmerge (§3): reconstruct the pre-merge length
    by gathering each original position's slot.  Composes across layers by
    chaining slot maps outermost-first."""
    return y[slot_map]


def compose_slot_maps(maps):
    """Chain per-layer slot maps into original-position -> final-slot
    (the merge trace of fig. 8).  ``maps`` is ordered layer 1 .. L."""
    acc = maps[0]
    for m in maps[1:]:
        acc = m[acc]
    return acc


def dynamic_mask_merge(x, *, threshold, k=1, metric="cos"):
    """Dynamic merging (§5.5) with static shapes.

    Pairs whose similarity exceeds ``threshold`` are replaced in place by
    their average (merge followed by immediate clone-unmerge), and the
    effective token count ``t - merged`` is returned for the FLOPs model.
    Quality matches true dynamic merging; the compute saving is accounted
    analytically (DESIGN.md §3, fig. 4 reports FLOPs for the same reason
    the paper does: "substantial execution overhead in time measurements").
    """
    t, _ = x.shape
    te = t - (t % 2)
    t2 = te // 2
    node_max, best_j = _match(x, k=k, metric=metric)
    do_merge = node_max > threshold                          # (t2,)
    a = x[0:te:2]
    merged_val = jax.ops.segment_sum(
        jnp.where(do_merge[:, None], a, 0.0), best_j, num_segments=t2
    )
    merged_cnt = jax.ops.segment_sum(
        do_merge.astype(jnp.float32), best_j, num_segments=t2
    )
    b = x[1:te:2]
    new_b = (b + merged_val) / (1.0 + merged_cnt)[:, None]
    # A tokens that merged take their destination's value (clone-unmerge);
    # everything else passes through.
    new_a = jnp.where(do_merge[:, None], new_b[best_j], a)
    out = x.at[0:te:2].set(new_a).at[1:te:2].set(new_b)
    effective = t - jnp.sum(do_merge.astype(jnp.int32))
    return out, effective


def merge_schedule(t, *, r, num_layers, q=2):
    """Static per-layer token counts for a fixed-``r`` schedule.

    Applies ``r`` merges per layer while at least ``q`` tokens remain
    (§3: ``q`` = minimum number of remaining tokens some architectures
    need).  Returns ``[t_1, ..., t_{L+1}]`` with ``t_1 = t``.
    """
    counts = [t]
    cur = t
    for _ in range(num_layers):
        step = min(r, (cur - (cur % 2)) // 2, max(0, cur - q))
        cur -= max(0, step)
        counts.append(cur)
    return counts
