"""AOT pipeline: lower every model variant to HLO text + manifest + weights.

``make artifacts`` runs this once; Rust is self-contained afterwards.

Interchange is HLO **text** (never ``.serialize()``): jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Registry layout: each *identity* (model family + size) owns one seeded
weights file shared by all of its merge variants — merging accelerates an
already-trained model (§5.1), so every ``r`` variant of an identity must
bind the same weights.  Each *variant* is one HLO artifact + manifest.

Kernel backend per artifact (DESIGN.md §6): performance-benchmarked
variants lower the XLA-fused reference path (bit-identical math, verified
against the Pallas kernels by pytest); ``*_pallas`` variants lower the
interpret-mode Pallas kernels to prove the L1 path round-trips through the
Rust PJRT runtime.  Interpret-mode overheads on CPU would otherwise
swamp wall-clock comparisons; real-TPU Pallas performance is estimated
analytically in DESIGN.md §6.

Usage:
  python -m compile.aot --out-dir ../artifacts [--only REGEX] [--force]
                        [--full] [--list] [--jobs N]
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import re
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import formats, merging, train
from .kernels import dispatch
from .models import chronos as Ch
from .models import decoder_only as Do
from .models import hyena as Hy
from .models import mamba as Ma
from .models import patchtst as Pt
from .models import transformer as T

# ---------------------------------------------------------------------------
# Lowering


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    # return_tuple=False: PJRT then splits the root into one buffer per
    # output, which is what lets the Rust training loop keep params /
    # optimiser state device-resident across steps (EXPERIMENTS.md §Perf).
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    text = comp.as_hlo_text()
    # Compatibility shim for xla_extension 0.5.1's HLO text parser: modern
    # jax emits `topk(..., k=N, largest=true)` but 0.5.1 only accepts the
    # `k` attribute (its TopK was largest-only, so semantics are identical).
    return text.replace(", largest=true", "")


def _seed(identity: str) -> int:
    return int.from_bytes(hashlib.sha256(identity.encode()).digest()[:4], "little")


@dataclasses.dataclass
class Artifact:
    name: str                 # artifact file stem
    identity: str             # weights-file stem (shared across variants)
    family: str               # forecast | chronos | chronos_dyn | hyena | ...
    backend: str              # "jnp" (fused) | "pallas"
    build: "callable"         # () -> (fn, params, inputs[(name, spec)], config, meta)
    core: bool = True         # lowered by default (--full adds the rest)


# ---------------------------------------------------------------------------
# Builders (each returns fn(params, *inputs), params, inputs, config, meta)


def _forecast(cfg: T.ForecastConfig, identity, batch):
    def build():
        params = T.init_params(jax.random.PRNGKey(_seed(identity)), cfg)
        fn = lambda p, x: T.forward_batch(p, x, cfg)
        inputs = [("x", jax.ShapeDtypeStruct((batch, cfg.m, cfg.n_vars), jnp.float32))]
        meta = {
            "enc_tokens": T.enc_token_counts(cfg),
            "dec_tokens": T.dec_token_counts(cfg),
            "batch": batch,
        }
        return fn, params, inputs, dataclasses.asdict(cfg), meta
    return build


def _forecast_train(cfg: T.ForecastConfig, identity, batch, lr):
    def build():
        params = T.init_params(jax.random.PRNGKey(_seed(identity)), cfg)
        base_step = train.make_forecast_train_step(T.forward_batch, cfg, lr=lr)
        step = train.make_chunked(base_step, TRAIN_CHUNK)
        zeros = jax.tree.map(jnp.zeros_like, params)
        inputs = [
            ("m", zeros), ("v", zeros),
            ("step", jax.ShapeDtypeStruct((), jnp.float32)),
            ("x", jax.ShapeDtypeStruct((TRAIN_CHUNK, batch, cfg.m, cfg.n_vars), jnp.float32)),
            ("y", jax.ShapeDtypeStruct((TRAIN_CHUNK, batch, cfg.p, cfg.n_vars), jnp.float32)),
        ]
        return step, params, inputs, dataclasses.asdict(cfg), {"batch": batch, "lr": lr, "chunk": TRAIN_CHUNK}
    return build


def _chronos(cfg: Ch.ChronosConfig, identity, batch):
    def build():
        params = Ch.init_params(jax.random.PRNGKey(_seed(identity)), cfg)
        fn = lambda p, x: Ch.forward_batch(p, x, cfg)
        inputs = [("x", jax.ShapeDtypeStruct((batch, cfg.m), jnp.float32))]
        meta = {
            "enc_tokens": merging.merge_schedule(
                cfg.m, r=cfg.r_enc, num_layers=cfg.enc_layers, q=cfg.q_min),
            "dec_tokens": merging.merge_schedule(
                cfg.p, r=cfg.r_dec, num_layers=cfg.dec_layers, q=cfg.q_min),
            "batch": batch,
        }
        return fn, params, inputs, dataclasses.asdict(cfg), meta
    return build


def _chronos_dyn(cfg: Ch.ChronosConfig, identity, batch):
    def build():
        params = Ch.init_params(jax.random.PRNGKey(_seed(identity)), cfg)
        fn = lambda p, x, th: Ch.forward_dynamic_batch(p, x, th, cfg)
        inputs = [
            ("x", jax.ShapeDtypeStruct((batch, cfg.m), jnp.float32)),
            ("threshold", jax.ShapeDtypeStruct((), jnp.float32)),
        ]
        return fn, params, inputs, dataclasses.asdict(cfg), {"batch": batch}
    return build


def _chronos_train(cfg: Ch.ChronosConfig, identity, batch, lr):
    def build():
        params = Ch.init_params(jax.random.PRNGKey(_seed(identity)), cfg)
        base_step = train.make_chronos_train_step(Ch.forward_batch, Ch.tokenize, cfg, lr=lr)
        step = train.make_chunked(base_step, TRAIN_CHUNK)
        zeros = jax.tree.map(jnp.zeros_like, params)
        inputs = [
            ("m", zeros), ("v", zeros),
            ("step", jax.ShapeDtypeStruct((), jnp.float32)),
            ("x", jax.ShapeDtypeStruct((TRAIN_CHUNK, batch, cfg.m), jnp.float32)),
            ("y", jax.ShapeDtypeStruct((TRAIN_CHUNK, batch, cfg.p), jnp.float32)),
        ]
        return step, params, inputs, dataclasses.asdict(cfg), {"batch": batch, "lr": lr, "chunk": TRAIN_CHUNK}
    return build


def _classify(mod, cfg, identity, batch):
    def build():
        params = mod.init_params(jax.random.PRNGKey(_seed(identity)), cfg)
        fn = lambda p, x: mod.forward_batch(p, x, cfg)
        inputs = [("ids", jax.ShapeDtypeStruct((batch, cfg.m), jnp.int32))]
        meta = {
            "tokens": merging.merge_schedule(
                cfg.m, r=cfg.r, num_layers=cfg.layers, q=cfg.q_min),
            "batch": batch,
        }
        return fn, params, inputs, dataclasses.asdict(cfg), meta
    return build


def _classify_train(mod, cfg, identity, batch, lr):
    def build():
        params = mod.init_params(jax.random.PRNGKey(_seed(identity)), cfg)
        base_step = train.make_classify_train_step(mod.forward_batch, cfg, lr=lr)
        step = train.make_chunked(base_step, TRAIN_CHUNK)
        zeros = jax.tree.map(jnp.zeros_like, params)
        inputs = [
            ("m", zeros), ("v", zeros),
            ("step", jax.ShapeDtypeStruct((), jnp.float32)),
            ("x", jax.ShapeDtypeStruct((TRAIN_CHUNK, batch, cfg.m), jnp.int32)),
            ("y", jax.ShapeDtypeStruct((TRAIN_CHUNK, batch,), jnp.int32)),
        ]
        return step, params, inputs, dataclasses.asdict(cfg), {"batch": batch, "lr": lr, "chunk": TRAIN_CHUNK}
    return build


def _patchtst(cfg: Pt.PatchTSTConfig, identity, batch):
    def build():
        params = Pt.init_params(jax.random.PRNGKey(_seed(identity)), cfg)
        fn = lambda p, x: Pt.forward_batch(p, x, cfg)
        inputs = [("x", jax.ShapeDtypeStruct((batch, cfg.m, cfg.n_vars), jnp.float32))]
        return fn, params, inputs, dataclasses.asdict(cfg), {"batch": batch}
    return build


def _patchtst_train(cfg: Pt.PatchTSTConfig, identity, batch, lr):
    def build():
        params = Pt.init_params(jax.random.PRNGKey(_seed(identity)), cfg)
        base_step = train.make_forecast_train_step(Pt.forward_batch, cfg, lr=lr)
        step = train.make_chunked(base_step, TRAIN_CHUNK)
        zeros = jax.tree.map(jnp.zeros_like, params)
        inputs = [
            ("m", zeros), ("v", zeros),
            ("step", jax.ShapeDtypeStruct((), jnp.float32)),
            ("x", jax.ShapeDtypeStruct((TRAIN_CHUNK, batch, cfg.m, cfg.n_vars), jnp.float32)),
            ("y", jax.ShapeDtypeStruct((TRAIN_CHUNK, batch, cfg.p, cfg.n_vars), jnp.float32)),
        ]
        return step, params, inputs, dataclasses.asdict(cfg), {"batch": batch, "lr": lr, "chunk": TRAIN_CHUNK}
    return build




def _deconly(cfg, identity, batch):
    def build():
        params = Do.init_params(jax.random.PRNGKey(_seed(identity)), cfg)
        fn = lambda p, x: Do.forward_batch(p, x, cfg)
        inputs = [("x", jax.ShapeDtypeStruct((batch, cfg.m), jnp.float32))]
        meta = {"tokens": Do.token_counts(cfg), "batch": batch}
        return fn, params, inputs, dataclasses.asdict(cfg), meta
    return build


def _deconly_train(cfg, identity, batch, lr):
    def build():
        params = Do.init_params(jax.random.PRNGKey(_seed(identity)), cfg)
        base_step = train.make_forecast_train_step(Do.forward_batch, cfg, lr=lr)
        step = train.make_chunked(base_step, TRAIN_CHUNK)
        zeros = jax.tree.map(jnp.zeros_like, params)
        inputs = [
            ("m", zeros), ("v", zeros),
            ("step", jax.ShapeDtypeStruct((), jnp.float32)),
            ("x", jax.ShapeDtypeStruct((TRAIN_CHUNK, batch, cfg.m), jnp.float32)),
            ("y", jax.ShapeDtypeStruct((TRAIN_CHUNK, batch, cfg.p), jnp.float32)),
        ]
        return step, params, inputs, dataclasses.asdict(cfg), {"batch": batch, "lr": lr, "chunk": TRAIN_CHUNK}
    return build


# ---------------------------------------------------------------------------
# Registry

ARCHS = ["transformer", "informer", "autoformer", "fedformer", "nonstationary"]
TRAIN_CHUNK = 4  # optimiser steps scanned per execution (see train.make_chunked)
FORECAST_BATCH = 8
GENOMIC_BATCH = 4


def registry():
    arts: list[Artifact] = []

    # ---- Table 1 suite: 5 archs x L x merge variants --------------------
    for arch in ARCHS:
        for L, core in [(2, True), (4, True), (6, False)]:
            identity = f"fc_{arch}_L{L}"
            for tag, r_enc, r_dec in [("r0", 0, 0), ("r16", 16, 48),
                                      ("r32", 32, 48)]:
                cfg = T.ForecastConfig(arch=arch, enc_layers=L,
                                       r_enc=r_enc, r_dec=r_dec)
                arts.append(Artifact(f"{identity}__{tag}", identity, "forecast",
                                     "jnp", _forecast(cfg, identity, FORECAST_BATCH),
                                     core=core))
            cfg0 = T.ForecastConfig(arch=arch, enc_layers=L)
            arts.append(Artifact(f"{identity}__train", identity, "forecast_train",
                                 "jnp", _forecast_train(cfg0, identity,
                                                        FORECAST_BATCH, 1e-3),
                                 core=core))
    # table 5: layer-1 token-representation probes
    for arch in ARCHS:
        identity = f"fc_{arch}_L2"
        cfgp = T.ForecastConfig(arch=arch, enc_layers=2, probe="tokens")
        arts.append(Artifact(f"{identity}__r0_probe", identity, "forecast",
                             "jnp", _forecast(cfgp, identity, FORECAST_BATCH)))
    # fig. 2: training *with* merging
    for arch in ["autoformer", "nonstationary"]:
        identity = f"fc_{arch}_L2"
        cfgm = T.ForecastConfig(arch=arch, enc_layers=2, r_enc=16, r_dec=48)
        arts.append(Artifact(f"{identity}__trainmerge", identity,
                             "forecast_train", "jnp",
                             _forecast_train(cfgm, identity, FORECAST_BATCH, 1e-3)))

    # ---- Chronos suite ----------------------------------------------------
    for size, scfg in Ch.SIZES.items():
        identity = f"chronos_{size}"
        for r in [0, 32, 64, 128]:
            cfg = Ch.ChronosConfig(r_enc=r, r_dec=16 if r else 0, **scfg)
            arts.append(Artifact(f"{identity}__r{r}", identity, "chronos", "jnp",
                                 _chronos(cfg, identity, FORECAST_BATCH)))
        cfg0 = Ch.ChronosConfig(**scfg)
        arts.append(Artifact(f"{identity}__train", identity, "chronos_train",
                             "jnp", _chronos_train(cfg0, identity,
                                                   FORECAST_BATCH, 1e-3)))

    s = Ch.SIZES["s"]
    sid = "chronos_s"
    # fig. 15: similarity metric ablation
    for metric in ["l1", "l2"]:
        cfg = Ch.ChronosConfig(r_enc=64, r_dec=16, metric=metric, **s)
        arts.append(Artifact(f"{sid}__r64_{metric}", sid, "chronos", "jnp",
                             _chronos(cfg, sid, FORECAST_BATCH), core=False))
    # fig. 16: pruning baseline
    cfg = Ch.ChronosConfig(r_enc=64, r_dec=0, prune=True, **s)
    arts.append(Artifact(f"{sid}__r64_prune", sid, "chronos", "jnp",
                         _chronos(cfg, sid, FORECAST_BATCH)))
    # table 5 / fig 19 probes
    cfg = Ch.ChronosConfig(probe="tokens", **s)
    arts.append(Artifact(f"{sid}__r0_probe", sid, "chronos", "jnp",
                         _chronos(cfg, sid, FORECAST_BATCH)))
    cfg = Ch.ChronosConfig(probe="tokens", use_pos_embed=False, **s)
    arts.append(Artifact(f"{sid}__r0_probe_nope", sid, "chronos", "jnp",
                         _chronos(cfg, sid, FORECAST_BATCH), core=False))
    # fig. 8 merge trace
    cfg = Ch.ChronosConfig(r_enc=64, r_dec=0, probe="trace", **s)
    arts.append(Artifact(f"{sid}__r64_trace", sid, "chronos", "jnp",
                         _chronos(cfg, sid, FORECAST_BATCH), core=False))
    # fig. 4 dynamic merging (threshold is a runtime input)
    for b in [1, 10]:
        cfg = Ch.ChronosConfig(**s)
        arts.append(Artifact(f"{sid}__dyn_b{b}", sid, "chronos_dyn", "jnp",
                             _chronos_dyn(cfg, sid, b)))
    # fig. 7 / 20: input-length variants (weights are m-independent)
    for m in [128, 256, 1024]:
        for r in [0, m // 8]:
            cfg = Ch.ChronosConfig(m=m, r_enc=r, r_dec=16 if r else 0, **s)
            arts.append(Artifact(f"{sid}__m{m}_r{r}", sid, "chronos", "jnp",
                                 _chronos(cfg, sid, FORECAST_BATCH), core=False))
    # L1 Pallas round-trip proof artifacts
    cfg = Ch.ChronosConfig(r_enc=64, r_dec=16, **s)
    arts.append(Artifact(f"{sid}__r64_pallas", sid, "chronos", "pallas",
                         _chronos(cfg, sid, 2)))

    # ---- locality-constraint ablation: k sweep at fixed r ------------------
    for k in [1, 4, 16, 64]:
        cfg = Ch.ChronosConfig(r_enc=64, r_dec=16, k_enc=k, **s)
        arts.append(Artifact(f"{sid}__r64_k{k}", sid, "chronos", "jnp",
                             _chronos(cfg, sid, FORECAST_BATCH)))

    # ---- decoder-only forecaster (causal merging showcase) -----------------
    did = "deconly_L4"
    for r in [0, 4, 8]:
        cfg = Do.DecoderOnlyConfig(r=r)
        arts.append(Artifact(f"{did}__r{r}", did, "deconly", "jnp",
                             _deconly(cfg, did, FORECAST_BATCH)))
    cfg0 = Do.DecoderOnlyConfig()
    arts.append(Artifact(f"{did}__train", did, "deconly_train", "jnp",
                         _deconly_train(cfg0, did, FORECAST_BATCH, 1e-3)))

    # ---- State-space suite (table 3) --------------------------------------
    hid, mid = "hyena_L4", "mamba_L4"
    for r, k_name, k in [(0, "", 1), (64, "_k1", 1), (128, "_k1", 1),
                         (64, "_kglobal", 10**6), (128, "_kglobal", 10**6)]:
        tag = f"r{r}{k_name}" if r else "r0"
        hcfg = Hy.HyenaConfig(r=r, k=k)
        mcfg = Ma.MambaConfig(r=r, k=k)
        arts.append(Artifact(f"{hid}__{tag}", hid, "hyena", "jnp",
                             _classify(Hy, hcfg, hid, GENOMIC_BATCH)))
        arts.append(Artifact(f"{mid}__{tag}", mid, "mamba", "jnp",
                             _classify(Ma, mcfg, mid, GENOMIC_BATCH)))
        if r == 0:
            arts.append(Artifact(f"{hid}__train", hid, "classify_train", "jnp",
                                 _classify_train(Hy, hcfg, hid, GENOMIC_BATCH, 1e-3)))
            arts.append(Artifact(f"{mid}__train", mid, "classify_train", "jnp",
                                 _classify_train(Ma, mcfg, mid, GENOMIC_BATCH, 1e-3)))
    # Pallas round-trip for the SSM scan kernel
    mcfg = Ma.MambaConfig(r=64, k=1, m=256, layers=2)
    arts.append(Artifact("mamba_L2s__r64_pallas", "mamba_L2s", "mamba", "pallas",
                         _classify(Ma, mcfg, "mamba_L2s", 2)))

    # ---- PatchTST (table 8) ------------------------------------------------
    pid = "patchtst_L2"
    for r in [0, 4, 8]:
        cfg = Pt.PatchTSTConfig(r=r)
        arts.append(Artifact(f"{pid}__r{r}", pid, "patchtst", "jnp",
                             _patchtst(cfg, pid, FORECAST_BATCH)))
    cfg = Pt.PatchTSTConfig()
    arts.append(Artifact(f"{pid}__train", pid, "patchtst_train", "jnp",
                         _patchtst_train(cfg, pid, FORECAST_BATCH, 1e-3)))

    return arts


# ---------------------------------------------------------------------------
# Golden outputs: for a subset of artifacts, evaluate the jitted function in
# Python on a fixed seeded input and persist (inputs, outputs) so the Rust
# integration tests can verify the full HLO round-trip numerically.

GOLDEN = [
    "fc_transformer_L2__r16",
    "fc_autoformer_L2__r0",
    "chronos_s__r64",
    "chronos_s__r64_pallas",
    "mamba_L2s__r64_pallas",
    "hyena_L4__r64_k1",
    "patchtst_L2__r4",
]


def write_golden(art: Artifact, out_dir: str):
    import numpy as np

    with dispatch.backend(art.backend):
        fn, params, inputs, _, _ = art.build()
        rng = np.random.default_rng(_seed(art.name))
        concrete = []
        for _, spec in inputs:
            assert isinstance(spec, jax.ShapeDtypeStruct)
            if spec.dtype == jnp.int32:
                concrete.append(rng.integers(0, 5, spec.shape).astype(np.int32))
            else:
                concrete.append(rng.standard_normal(spec.shape).astype(np.float32))
        outs = jax.tree_util.tree_leaves(jax.jit(fn)(params, *concrete))
    tree = {}
    for i, c in enumerate(concrete):
        tree[f"in{i}"] = c
    for i, o in enumerate(outs):
        arr = np.asarray(o)
        if arr.dtype not in (np.float32, np.int32):
            arr = arr.astype(np.float32)
        tree[f"out{i}"] = arr
    formats.write_weights(os.path.join(out_dir, f"{art.name}.golden.bin"), tree)


# ---------------------------------------------------------------------------
# Manifest merge_spec

# Mirrors the Rust side's "k = 0 means global pool" convention: the band
# half-width is clamped to t/2 inside the kernel, so any huge value acts
# as "unbounded" (config.rs uses the same sentinel).
GLOBAL_K = 10**6


def merge_spec_for(family, config, meta):
    """Derive the manifest ``merge_spec`` block for an inference artifact.

    Emits the same JSON dialect the Rust loader parses strictly
    (``config::merge_spec_from_json`` — unknown keys rejected, schedule
    entries >= 1, ``causal`` implies ``k == 1``); the serving coordinator
    prefers this block over its own config.  Returns ``None`` for
    artifacts that never premerge (training steps) or whose merge rate is
    chosen at serve time (``chronos_dyn``).

    The fixed-mode schedule is the per-layer merge counts: positive
    diffs of the builder's token-count meta, dropping layers where the
    ``q_min`` floor made the step zero.
    """
    if family in ("forecast", "chronos"):
        counts, k, causal = meta.get("enc_tokens"), config["k_enc"], False
    elif family in ("hyena", "mamba"):
        counts, k, causal = meta.get("tokens"), config["k"], False
    elif family == "deconly":
        # Decoder-only merging is causal: band k = 1 always (§3.3).
        counts, k, causal = meta.get("tokens"), 1, True
    elif family == "patchtst":
        # PatchTST builders carry no token meta; recompute the schedule
        # from the patching geometry.
        n_patches = (config["m"] - config["patch_len"]) // config["stride"] + 1
        counts = merging.merge_schedule(n_patches, r=config["r"],
                                        num_layers=config["layers"],
                                        q=config["q_min"])
        k, causal = config["k"], False
    else:
        return None
    if counts is None:
        return None
    schedule = [a - b for a, b in zip(counts, counts[1:]) if a > b]
    if not schedule:
        return {"mode": "off"}
    spec = {"mode": "fixed", "k": k if k >= 1 else GLOBAL_K,
            "schedule": schedule}
    if causal:
        spec["causal"] = True
    return spec


# ---------------------------------------------------------------------------
# Driver


def lower_artifact(art: Artifact, out_dir: str, force: bool) -> str:
    hlo_path = os.path.join(out_dir, f"{art.name}.hlo.txt")
    man_path = os.path.join(out_dir, f"{art.name}.json")
    w_path = os.path.join(out_dir, f"{art.identity}.weights.bin")
    if not force and os.path.exists(hlo_path) and os.path.exists(man_path) \
            and os.path.exists(w_path):
        return "skip"
    with dispatch.backend(art.backend):
        fn, params, inputs, config, meta = art.build()
        if not os.path.exists(w_path) or force:
            formats.write_weights(w_path, params)
        specs = []
        named_inputs = []
        for name, spec in inputs:
            if isinstance(spec, jax.ShapeDtypeStruct):
                specs.append(spec)
                named_inputs.append((name, spec))
            else:  # a pytree (optimizer state mirroring params)
                tree_spec = jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), spec)
                specs.append(tree_spec)
                named_inputs.extend(
                    (f"{name}/{n}", jax.ShapeDtypeStruct(tuple(a.shape), a.dtype))
                    for n, a in formats.flatten_named(spec))
        # keep_unused: the manifest lists every flattened param; XLA must not
        # drop ones a particular variant happens not to touch.
        lowered = jax.jit(fn, keep_unused=True).lower(params, *specs)
        out_shape = jax.eval_shape(fn, params, *specs)
        outputs = [(f"out{i}", s) for i, s in
                   enumerate(jax.tree_util.tree_leaves(out_shape))]
        text = to_hlo_text(lowered)
    with open(hlo_path, "w") as f:
        f.write(text)
    meta = dict(meta)
    meta["backend"] = art.backend
    formats.write_manifest(man_path, name=art.name, family=art.family,
                           config=config, params_tree=params,
                           inputs=named_inputs, outputs=outputs, meta=meta,
                           merge_spec=merge_spec_for(art.family, config, meta))
    return "ok"


def _worker(args):
    # Closures are not picklable under spawn: workers rebuild the registry
    # and look the artifact up by name.
    name, out_dir, force = args
    try:
        art = next(a for a in registry() if a.name == name)
        status = lower_artifact(art, out_dir, force)
        if name in GOLDEN:
            golden_path = os.path.join(out_dir, f"{name}.golden.bin")
            if force or not os.path.exists(golden_path):
                write_golden(art, out_dir)
                status = "ok"
        return name, status, ""
    except Exception as e:  # pragma: no cover - surfaced to the console
        return name, "FAIL", f"{type(e).__name__}: {e}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="also lower non-core (ablation) artifacts")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--jobs", type=int, default=max(2, (os.cpu_count() or 4) // 2))
    args = ap.parse_args()

    arts = registry()
    if not args.full:
        arts = [a for a in arts if a.core]
    if args.only:
        rx = re.compile(args.only)
        arts = [a for a in arts if rx.search(a.name)]
    if args.list:
        for a in arts:
            print(f"{a.name:40s} {a.family:16s} backend={a.backend}")
        return

    os.makedirs(args.out_dir, exist_ok=True)
    results = []
    todo = [(a.name, args.out_dir, args.force) for a in arts]
    if args.jobs > 1:
        import multiprocessing as mp
        ctx = mp.get_context("spawn")
        with ctx.Pool(args.jobs) as pool:
            for name, status, err in pool.imap_unordered(_worker, todo):
                print(f"[{status:4s}] {name} {err}", flush=True)
                results.append((name, status))
    else:
        for item in todo:
            name, status, err = _worker(item)
            print(f"[{status:4s}] {name} {err}", flush=True)
            results.append((name, status))

    index = {
        "artifacts": [
            {"name": a.name, "identity": a.identity, "family": a.family,
             "backend": a.backend}
            for a in arts
        ]
    }
    with open(os.path.join(args.out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1, sort_keys=True)

    failed = [n for n, s in results if s == "FAIL"]
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)
    print(f"{len(results)} artifacts up to date in {args.out_dir}")


if __name__ == "__main__":
    main()
