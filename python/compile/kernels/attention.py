"""Layer-1 Pallas fused attention kernel.

The transformer hot loop: one kernel invocation computes
``softmax(Q K^T * scale + size_bias + mask) V`` for one head and one
row-block of queries.  ``size_bias`` implements ToMe *proportional
attention* (Bolya et al. 2023): after merging, each token carries a size
``s`` and attends with an additive ``log s`` bias on the key axis so a
merged token counts as the ``s`` originals it represents.

TPU adaptation (DESIGN.md §6): queries are tiled over the grid
(flash-attention row blocking) while K/V for the head stay resident —
sequence lengths in this domain (<= 1024 tokens after the tokenizer) fit
comfortably in VMEM, so the numerically-streamed softmax of true flash
attention is unnecessary; a row-blocked stable softmax is the better
structure.  ``interpret=True`` for CPU PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 32


def _attn_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, scale):
    q = q_ref[0].astype(jnp.float32)              # (bq, dh)
    k = k_ref[0].astype(jnp.float32)              # (t, dh)
    v = v_ref[0].astype(jnp.float32)              # (t, dh)
    bias = bias_ref[...].astype(jnp.float32)      # (bq, t) additive
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale + bias
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    w = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jax.lax.dot_general(
        w, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block",))
def fused_attention(q, k, v, bias, *, block=DEFAULT_BLOCK):
    """Multi-head attention with an additive bias.

    q, k, v: ``(h, t, dh)``; bias: ``(t, t)`` broadcast over heads
    (causal mask and/or proportional-attention ``log size`` already folded
    in by the caller).  Returns ``(h, t, dh)`` float32.
    """
    h, t, dh = q.shape
    assert k.shape == (h, t, dh) and v.shape == (h, t, dh)
    assert bias.shape == (t, t)
    bq = block if t % block == 0 else t
    grid = (h, t // bq)
    scale = 1.0 / float(dh) ** 0.5
    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda hh, i: (hh, i, 0)),
            pl.BlockSpec((1, t, dh), lambda hh, i: (hh, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda hh, i: (hh, 0, 0)),
            pl.BlockSpec((bq, t), lambda hh, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda hh, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, dh), jnp.float32),
        interpret=True,
    )(q, k, v, bias)
