"""Layer-1 Pallas selective-scan kernel (Mamba-style S6 recurrence).

Computes, per channel ``c`` and state dim ``n``::

    h_t = exp(dt_t A) * h_{t-1} + dt_t B_t x_t
    y_t = <h_t, C_t> + D x_t

The grid tiles the channel axis; within a tile the recurrence runs as a
``lax.scan`` over time (sequential in t — exactly the structure Mamba's
hardware-aware kernel parallelises over channels while scanning time).

TPU adaptation (DESIGN.md §6): Mamba's CUDA kernel keeps ``h`` in SRAM and
fuses the discretisation; here the channel-block of ``h`` lives in VMEM
(``block * n`` floats) and the discretisation (``exp(dt A)``, ``dt B x``)
is fused into the scan body.  ``interpret=True`` for CPU PJRT.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 16


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)    # (t, bc)
    dt = dt_ref[...].astype(jnp.float32)  # (t, bc)
    a = a_ref[...].astype(jnp.float32)    # (bc, n)
    b = b_ref[...].astype(jnp.float32)    # (t, n)
    c = c_ref[...].astype(jnp.float32)    # (t, n)
    d = d_ref[...].astype(jnp.float32)    # (bc,)

    da = jnp.exp(dt[:, :, None] * a[None, :, :])          # (t, bc, n)
    dbx = dt[:, :, None] * b[:, None, :] * x[:, :, None]  # (t, bc, n)

    def step(h, inp):
        da_t, dbx_t, c_t = inp
        h = da_t * h + dbx_t
        return h, jnp.sum(h * c_t[None, :], axis=-1)

    h0 = jnp.zeros(a.shape, jnp.float32)
    _, ys = jax.lax.scan(step, h0, (da, dbx, c))          # ys: (t, bc)
    o_ref[...] = ys + x * d[None, :]


@functools.partial(jax.jit, static_argnames=("block",))
def selective_scan(x, dt, a, b, c, d, *, block=DEFAULT_BLOCK):
    """Selective state-space scan.

    x, dt: ``(t, dch)``;  a: ``(dch, n)``;  b, c: ``(t, n)``;  d: ``(dch,)``.
    Returns y ``(t, dch)`` float32.  Matches ``ref.ssm_scan_ref``.
    """
    t, dch = x.shape
    n = a.shape[1]
    bc = block if dch % block == 0 else dch
    grid = (dch // bc,)
    return pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, bc), lambda i: (0, i)),
            pl.BlockSpec((t, bc), lambda i: (0, i)),
            pl.BlockSpec((bc, n), lambda i: (i, 0)),
            pl.BlockSpec((t, n), lambda i: (0, 0)),
            pl.BlockSpec((t, n), lambda i: (0, 0)),
            pl.BlockSpec((bc,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((t, bc), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((t, dch), jnp.float32),
        interpret=True,
    )(x, dt, a, b, c, d)
