"""Layer-1 Pallas kernels for local token merging (paper §3, fig. 1).

The compute hot-spot of the paper's contribution is the similarity step of
token merging:

* **banded similarity** — the *local merging* variant: cosine similarity of
  the alternating subsets A and B restricted to the band ``|i - j| < k``
  (eq. 1).  Following §3 ("for efficient computation, we refactor S_loc
  into a rectangular tensor"), the band is materialised as a rectangular
  ``(t/2, 2k-1)`` tensor, giving the ``O(t/2 + (k-1)(t-k))`` complexity of
  eq. 2 instead of the quadratic ``O(t^2/4)`` of global merging.

* **full similarity** — the *global merging* pool (``k = t/2``), a tiled
  ``A_norm @ B_norm^T`` matmul.

TPU adaptation (DESIGN.md §6): the banded kernel streams three
``(block, d)`` windows of B (previous / current / next row-block) through
VMEM so the band never requires the full ``t/2 x t/2`` score matrix in
memory; the full-similarity kernel tiles rows of A against a resident B.
Both run under ``interpret=True`` here (CPU PJRT cannot execute Mosaic
custom-calls) — block shapes are still chosen MXU/VPU friendly
(multiples of 8 rows, d padded to 128 lanes at the call-site when needed).

All kernels are checked against the pure-jnp oracles in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Cosine similarity lives in [-1, 1]; out-of-band / invalid entries get a
# sentinel well below that so argmax/top-r never selects them.
NEG_INF = -1e9

# Row-block size for the banded kernel.  Must be >= k - 1 so the band of a
# row block is covered by (prev, cur, next) B blocks.
DEFAULT_BLOCK = 32


def _l2_normalize(x, eps=1e-8):
    return x * jax.lax.rsqrt(jnp.sum(x * x, axis=-1, keepdims=True) + eps)


def _banded_kernel(a_ref, bp_ref, bc_ref, bn_ref, o_ref, *, k, block, t2):
    """One row-block of the banded cosine-similarity tensor.

    a_ref:  (block, d)   rows i0..i0+block of A
    bp/bc/bn_ref: (block, d) previous / current / next row-blocks of B
    o_ref:  (block, 2k-1) scores for offsets -(k-1)..(k-1)
    """
    i0 = pl.program_id(0) * block
    a = _l2_normalize(a_ref[...].astype(jnp.float32))
    # Stack the three B windows: rows i0-block .. i0+2*block of B.
    b = jnp.concatenate(
        [bp_ref[...], bc_ref[...], bn_ref[...]], axis=0
    ).astype(jnp.float32)
    b = _l2_normalize(b)

    rows = i0 + jax.lax.iota(jnp.int32, block)  # global A-row index

    def offset_score(p, acc):
        # offset o = p - (k - 1) in [-(k-1), k-1]; B row j = i + o.
        o = p - (k - 1)
        # Local index into the stacked b window: (i - i0) + block + o.
        shifted = jax.lax.dynamic_slice_in_dim(b, block + o, block, axis=0)
        s = jnp.sum(a * shifted, axis=-1)
        j = rows + o
        valid = (j >= 0) & (j < t2) & (rows < t2)
        s = jnp.where(valid, s, NEG_INF)
        return acc.at[:, p].set(s)

    out = jax.lax.fori_loop(
        0, 2 * k - 1, offset_score, jnp.full((block, 2 * k - 1), NEG_INF, jnp.float32)
    )
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("k", "block"))
def banded_similarity(a, b, *, k, block=DEFAULT_BLOCK):
    """Rectangular banded cosine similarity ``S_loc`` (paper eq. 1).

    Args:
      a: ``(t2, d)`` tokens of subset A.
      b: ``(t2, d)`` tokens of subset B.
      k: locality constraint, ``1 <= k <= t2``.
    Returns:
      ``(t2, 2k-1)`` scores; column ``p`` is offset ``p - (k-1)``;
      out-of-range entries are ``NEG_INF``.
    """
    t2, d = a.shape
    assert b.shape == (t2, d)
    block = min(block, t2)
    # The three-window trick needs k - 1 <= block.
    while block < k - 1:
        block *= 2
    block = min(block, t2) if t2 % block == 0 else t2
    if t2 % block != 0:
        block = t2
    grid = t2 // block

    def b_idx(i, delta):
        # Clamp so boundary blocks read a valid (masked-out) window.
        return (jnp.clip(i + delta, 0, grid - 1), 0)

    return pl.pallas_call(
        functools.partial(_banded_kernel, k=k, block=block, t2=t2),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block, d), functools.partial(b_idx, delta=-1)),
            pl.BlockSpec((block, d), functools.partial(b_idx, delta=0)),
            pl.BlockSpec((block, d), functools.partial(b_idx, delta=1)),
        ],
        out_specs=pl.BlockSpec((block, 2 * k - 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t2, 2 * k - 1), jnp.float32),
        interpret=True,
    )(a, b, b, b)


def _full_kernel(a_ref, b_ref, o_ref):
    a = _l2_normalize(a_ref[...].astype(jnp.float32))
    b = _l2_normalize(b_ref[...].astype(jnp.float32))
    o_ref[...] = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block",))
def full_similarity(a, b, *, block=DEFAULT_BLOCK):
    """Global-merging similarity ``S = A_n @ B_n^T`` (``k = t/2`` pool)."""
    t2, d = a.shape
    assert b.shape == (t2, d)
    block = block if t2 % block == 0 else t2
    grid = t2 // block
    return pl.pallas_call(
        _full_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((t2, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, t2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t2, t2), jnp.float32),
        interpret=True,
    )(a, b)


def similarity(a, b, *, k):
    """Dispatch: banded local similarity, widened to the full ``(t2, t2)``
    layout when ``k`` already covers the global pool."""
    t2 = a.shape[0]
    if k >= t2:
        return full_similarity(a, b)
    return banded_similarity(a, b, k=k)
