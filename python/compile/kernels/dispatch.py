"""Kernel backend dispatch: Pallas (inference artifacts) vs jnp reference.

``pallas_call`` has no reverse-mode autodiff (even in interpret mode), so
training-step graphs are lowered with the pure-jnp reference path — which
pytest verifies bit-for-bit against the Pallas kernels — while inference
artifacts use the Pallas kernels.  ``aot.py`` flips the backend around each
lowering; models only ever import from this module.
"""

from __future__ import annotations

from contextlib import contextmanager

from . import attention as _attention
from . import local_merge as _local_merge
from . import ref as _ref
from . import ssm as _ssm

_BACKEND = "pallas"


def set_backend(name: str):
    global _BACKEND
    assert name in ("pallas", "jnp"), name
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


@contextmanager
def backend(name: str):
    prev = _BACKEND
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


def fused_attention(q, k, v, bias):
    if _BACKEND == "pallas":
        return _attention.fused_attention(q, k, v, bias)
    return _ref.attention_ref(q, k, v, mask=bias)


def banded_similarity(a, b, *, k):
    if _BACKEND == "pallas":
        return _local_merge.banded_similarity(a, b, k=k)
    return _ref.banded_similarity_ref(a, b, k=k)


def full_similarity(a, b):
    if _BACKEND == "pallas":
        return _local_merge.full_similarity(a, b)
    return _ref.full_similarity_ref(a, b)


def similarity(a, b, *, k):
    if k >= a.shape[0]:
        return full_similarity(a, b)
    return banded_similarity(a, b, k=k)


def selective_scan(x, dt, a, b, c, d):
    if _BACKEND == "pallas":
        return _ssm.selective_scan(x, dt, a, b, c, d)
    return _ref.ssm_scan_ref(x, dt, a, b, c, d)
