"""Pure-jnp oracles for every Pallas kernel (the pytest correctness signal).

Each function mirrors one kernel in this package with the most direct
possible jnp formulation — no tiling, no windows, no loops — so a mismatch
always points at the kernel, never at the oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def l2_normalize(x, eps=1e-8):
    return x * jax.lax.rsqrt(jnp.sum(x * x, axis=-1, keepdims=True) + eps)


def banded_similarity_ref(a, b, *, k):
    """Oracle for ``local_merge.banded_similarity``.

    Builds the full (t2, t2) cosine matrix and gathers the band
    ``|i - j| < k`` into the rectangular (t2, 2k-1) layout.
    """
    t2 = a.shape[0]
    s = l2_normalize(a.astype(jnp.float32)) @ l2_normalize(b.astype(jnp.float32)).T
    i = jnp.arange(t2)[:, None]
    p = jnp.arange(2 * k - 1)[None, :]
    j = i + p - (k - 1)
    valid = (j >= 0) & (j < t2)
    return jnp.where(valid, s[i, jnp.clip(j, 0, t2 - 1)], NEG_INF)


def full_similarity_ref(a, b):
    """Oracle for ``local_merge.full_similarity``."""
    return l2_normalize(a.astype(jnp.float32)) @ l2_normalize(b.astype(jnp.float32)).T


def attention_ref(q, k, v, *, mask=None, size_bias=None, scale=None):
    """Oracle for ``attention.fused_attention``.

    q,k,v: (h, t, dh).  mask: (t, t) additive or None.  size_bias: (t,)
    log-token-size bias for ToMe proportional attention or None.
    """
    dh = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(jnp.float32(dh))
    logits = jnp.einsum("htd,hsd->hts", q, k).astype(jnp.float32) * scale
    if size_bias is not None:
        logits = logits + size_bias[None, None, :]
    if mask is not None:
        logits = logits + mask[None, :, :]
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hts,hsd->htd", w, v.astype(jnp.float32))


def ssm_scan_ref(x, dt, a, b, c, d):
    """Oracle for ``ssm.selective_scan`` (Mamba-style S6 recurrence).

    x:  (t, dch)  input sequence
    dt: (t, dch)  positive step sizes (already softplus'ed)
    a:  (dch, n)  state matrix (negative real)
    b:  (t, n)    input->state projection (input dependent)
    c:  (t, n)    state->output projection (input dependent)
    d:  (dch,)    skip connection
    Returns y: (t, dch).
    """
    da = jnp.exp(dt[:, :, None] * a[None, :, :])            # (t, dch, n)
    dbx = dt[:, :, None] * b[:, None, :] * x[:, :, None]    # (t, dch, n)

    def step(h, inp):
        da_t, dbx_t, c_t = inp
        h = da_t * h + dbx_t
        y = jnp.sum(h * c_t[None, :], axis=-1)              # (dch,)
        return h, y

    dch, n = a.shape
    h0 = jnp.zeros((dch, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (da, dbx, c))
    return ys + x * d[None, :]
