"""Layer-2 facade: re-exports the model zoo (see ``models/``).

Kept for the canonical scaffold layout; the actual model definitions live
in ``compile/models/`` (transformer variants, chronos, hyena, mamba,
patchtst) and the merging ops in ``compile/merging.py``.
"""

from .merging import (  # noqa: F401
    dynamic_mask_merge,
    merge_causal,
    merge_fixed_r,
    merge_schedule,
    prune_fixed_r,
    unmerge,
)
from .models import chronos, hyena, mamba, patchtst, transformer  # noqa: F401
