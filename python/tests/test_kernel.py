"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes/dtypes; every property asserts allclose against
``ref.py``.  These tests gate ``make artifacts``: if they fail, no artifact
can be trusted.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, local_merge, ref, ssm

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def normal(rng, shape, dtype):
    return rng.standard_normal(shape).astype(dtype)


# ---------------------------------------------------------------------------
# banded / full similarity


@given(
    t2=st.integers(2, 96),
    d=st.integers(1, 64),
    k=st.integers(1, 96),
    dtype=st.sampled_from([np.float32, np.float16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_banded_similarity_matches_ref(t2, d, k, dtype, seed):
    k = min(k, t2)
    rng = np.random.default_rng(seed)
    a = normal(rng, (t2, d), dtype)
    b = normal(rng, (t2, d), dtype)
    got = np.asarray(local_merge.banded_similarity(a, b, k=k))
    want = np.asarray(ref.banded_similarity_ref(a, b, k=k))
    np.testing.assert_allclose(got, want, atol=2e-3 if dtype == np.float16 else 1e-5)


@given(t2=st.integers(2, 128), d=st.integers(1, 96), seed=st.integers(0, 2**31 - 1))
def test_full_similarity_matches_ref(t2, d, seed):
    rng = np.random.default_rng(seed)
    a = normal(rng, (t2, d), np.float32)
    b = normal(rng, (t2, d), np.float32)
    got = np.asarray(local_merge.full_similarity(a, b))
    want = np.asarray(ref.full_similarity_ref(a, b))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_banded_band_is_masked():
    rng = np.random.default_rng(0)
    a = normal(rng, (16, 8), np.float32)
    s = np.asarray(local_merge.banded_similarity(a, a, k=2))
    assert s.shape == (16, 3)
    # first row has no left neighbour; last row no right neighbour
    assert s[0, 0] <= ref.NEG_INF / 2
    assert s[-1, -1] <= ref.NEG_INF / 2


def test_banded_equals_full_on_diag():
    rng = np.random.default_rng(1)
    a = normal(rng, (32, 16), np.float32)
    b = normal(rng, (32, 16), np.float32)
    banded = np.asarray(local_merge.banded_similarity(a, b, k=1))[:, 0]
    full = np.asarray(local_merge.full_similarity(a, b)).diagonal()
    np.testing.assert_allclose(banded, full, atol=1e-5)


# ---------------------------------------------------------------------------
# fused attention


@given(
    h=st.integers(1, 8),
    t=st.integers(2, 96),
    dh=st.integers(1, 32),
    causal=st.booleans(),
    sizes=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(h, t, dh, causal, sizes, seed):
    rng = np.random.default_rng(seed)
    q = normal(rng, (h, t, dh), np.float32)
    k = normal(rng, (h, t, dh), np.float32)
    v = normal(rng, (h, t, dh), np.float32)
    bias = np.zeros((t, t), np.float32)
    size_bias = None
    if causal:
        bias += np.where(np.tril(np.ones((t, t), bool)), 0.0, -1e9).astype(np.float32)
    if sizes:
        sz = rng.integers(1, 5, (t,)).astype(np.float32)
        size_bias = np.log(sz)
        bias = bias + size_bias[None, :]
    got = np.asarray(attention.fused_attention(q, k, v, bias))
    mask = bias - (size_bias[None, :] if size_bias is not None else 0.0)
    want = np.asarray(ref.attention_ref(q, k, v, mask=mask, size_bias=size_bias))
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_attention_causality():
    """Perturbing a future token never changes past outputs."""
    rng = np.random.default_rng(2)
    h, t, dh = 2, 24, 8
    q = normal(rng, (h, t, dh), np.float32)
    k = normal(rng, (h, t, dh), np.float32)
    v = normal(rng, (h, t, dh), np.float32)
    bias = np.where(np.tril(np.ones((t, t), bool)), 0.0, -1e9).astype(np.float32)
    base = np.asarray(attention.fused_attention(q, k, v, bias))
    k2, v2 = k.copy(), v.copy()
    k2[:, -1] += 10.0
    v2[:, -1] -= 5.0
    pert = np.asarray(attention.fused_attention(q, k2, v2, bias))
    np.testing.assert_allclose(base[:, :-1], pert[:, :-1], atol=1e-6)
    assert not np.allclose(base[:, -1], pert[:, -1])


# ---------------------------------------------------------------------------
# selective scan


@given(
    t=st.integers(1, 64),
    dch=st.integers(1, 32),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_selective_scan_matches_ref(t, dch, n, seed):
    rng = np.random.default_rng(seed)
    x = normal(rng, (t, dch), np.float32)
    dt = np.abs(normal(rng, (t, dch), np.float32)) * 0.1
    a = -np.abs(normal(rng, (dch, n), np.float32))
    b = normal(rng, (t, n), np.float32)
    c = normal(rng, (t, n), np.float32)
    d = normal(rng, (dch,), np.float32)
    got = np.asarray(ssm.selective_scan(x, dt, a, b, c, d))
    want = np.asarray(ref.ssm_scan_ref(x, dt, a, b, c, d))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_selective_scan_state_decay():
    """With strongly negative A and large dt the scan forgets: output at t
    depends only weakly on inputs far in the past."""
    t, dch, n = 32, 4, 4
    rng = np.random.default_rng(3)
    x = normal(rng, (t, dch), np.float32)
    dt = np.full((t, dch), 5.0, np.float32)        # heavy decay
    a = -np.ones((dch, n), np.float32) * 5.0
    b = np.ones((t, n), np.float32)
    c = np.ones((t, n), np.float32)
    d = np.zeros((dch,), np.float32)
    y = np.asarray(ssm.selective_scan(x, dt, a, b, c, d))
    x2 = x.copy()
    x2[0] += 100.0                                  # perturb distant past
    y2 = np.asarray(ssm.selective_scan(x2, dt, a, b, c, d))
    assert np.abs(y2[-1] - y[-1]).max() < 1e-3
    assert np.abs(y2[0] - y[0]).max() > 1.0


# ---------------------------------------------------------------------------
# dispatch layer


def test_dispatch_backends_agree():
    from compile.kernels import dispatch

    rng = np.random.default_rng(4)
    a = normal(rng, (32, 16), np.float32)
    with dispatch.backend("pallas"):
        p = np.asarray(dispatch.banded_similarity(a, a, k=3))
    with dispatch.backend("jnp"):
        j = np.asarray(dispatch.banded_similarity(a, a, k=3))
    np.testing.assert_allclose(p, j, atol=1e-5)
    assert dispatch.get_backend() == "pallas"  # context restored


def test_dispatch_jnp_backend_is_differentiable():
    from compile.kernels import dispatch

    rng = np.random.default_rng(5)
    q = jnp.asarray(normal(rng, (2, 16, 8), np.float32))
    k = jnp.asarray(normal(rng, (2, 16, 8), np.float32))
    v = jnp.asarray(normal(rng, (2, 16, 8), np.float32))
    bias = jnp.zeros((16, 16))
    with dispatch.backend("jnp"):
        g = jax.grad(lambda q: dispatch.fused_attention(q, k, v, bias).sum())(q)
    assert g.shape == q.shape
    assert bool(jnp.isfinite(g).all())
