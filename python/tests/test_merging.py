"""L2 merging-op invariants (mirror of the Rust property suite, so the two
implementations are pinned to the same semantics)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import merging

settings.register_profile("merging", max_examples=20, deadline=None)
settings.load_profile("merging")


def rand(seed, *shape):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


@given(
    t=st.integers(6, 64),
    d=st.integers(1, 16),
    frac=st.floats(0.1, 1.0),
    k=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_merge_mass_conservation(t, d, frac, k, seed):
    t2 = (t - t % 2) // 2
    r = max(1, int(frac * t2))
    k = min(k, t2)
    x = jnp.asarray(rand(seed, t, d))
    sizes = jnp.ones((t,))
    res = merging.merge_fixed_r(x, sizes, r=r, k=k)
    assert res.x.shape == (t - r, d)
    np.testing.assert_allclose(float(res.sizes.sum()), t, rtol=1e-5)
    got = np.asarray(res.x * res.sizes[:, None]).sum(0)
    np.testing.assert_allclose(got, np.asarray(x).sum(0), atol=1e-3)


@given(t=st.integers(6, 48), d=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_causal_k1_adjacency(t, d, seed):
    t2 = (t - t % 2) // 2
    r = max(1, t2 // 2)
    x = jnp.asarray(rand(seed, t, d))
    res = merging.merge_causal(x, jnp.ones((t,)), r=r)
    sm = np.asarray(res.slot_map)
    for s in range(t - r):
        srcs = np.where(sm == s)[0]
        assert srcs.max() - srcs.min() <= 1, f"slot {s} spans {srcs}"


def test_merge_prefers_most_similar():
    # two identical token pairs + dissimilar fillers: r=2 must merge the
    # identical ones
    d = 4
    base = rand(0, 8, d) * 5
    x = base.copy()
    x[1] = x[0]          # pair (0, 1) identical (A0 with B0)
    x[3] = x[2]          # pair (2, 3) identical (A1 with B1)
    res = merging.merge_fixed_r(jnp.asarray(x), jnp.ones((8,)), r=2, k=1)
    sm = np.asarray(res.slot_map)
    assert sm[0] == sm[1]
    assert sm[2] == sm[3]


def test_prune_keeps_original_rows():
    x = rand(1, 20, 6)
    res = merging.prune_fixed_r(jnp.asarray(x), jnp.ones((20,)), r=5, k=3)
    rows = {tuple(np.round(r, 5)) for r in x}
    for row in np.asarray(res.x):
        assert tuple(np.round(row, 5)) in rows


def test_unmerge_and_compose():
    x = rand(2, 24, 4)
    s1 = merging.merge_fixed_r(jnp.asarray(x), jnp.ones((24,)), r=4, k=2)
    s2 = merging.merge_fixed_r(s1.x, s1.sizes, r=4, k=2)
    composed = merging.compose_slot_maps([s1.slot_map, s2.slot_map])
    assert composed.shape == (24,)
    um = merging.unmerge(s2.x, composed)
    assert um.shape == (24, 4)
    # every reconstructed row equals the merged token its position maps to
    for p in range(24):
        np.testing.assert_array_equal(np.asarray(um[p]),
                                      np.asarray(s2.x[int(composed[p])]))


@given(th=st.floats(-1.0, 1.0), seed=st.integers(0, 2**31 - 1))
def test_dynamic_effective_count_bounds(th, seed):
    x = jnp.asarray(rand(seed, 32, 8))
    out, eff = merging.dynamic_mask_merge(x, threshold=th, k=1)
    assert out.shape == x.shape
    assert 16 <= int(eff) <= 32


def test_dynamic_extremes():
    x = jnp.asarray(rand(3, 16, 4))
    out, eff = merging.dynamic_mask_merge(x, threshold=2.0, k=1)
    assert int(eff) == 16
    np.testing.assert_allclose(np.asarray(out), np.asarray(x))
    _, eff = merging.dynamic_mask_merge(x, threshold=-2.0, k=1)
    assert int(eff) == 8


def test_metrics_give_valid_merges():
    x = jnp.asarray(rand(4, 24, 8))
    for metric in ["cos", "l1", "l2"]:
        res = merging.merge_fixed_r(x, jnp.ones((24,)), r=4, k=3, metric=metric)
        assert res.x.shape == (20, 8)
        assert np.isfinite(np.asarray(res.x)).all()


def test_rank_desc_exact_selection():
    x = jnp.asarray(np.array([3.0, 1.0, 3.0, 2.0], np.float32))
    rank = np.asarray(merging.rank_desc(x))
    # ties broken by position: first 3.0 ranks 0, second ranks 1
    assert list(rank) == [0, 3, 1, 2]


def test_odd_length_excludes_most_recent():
    # t odd: the last token must always map to its own slot (never merged)
    x = rand(5, 21, 4)
    res = merging.merge_fixed_r(jnp.asarray(x), jnp.ones((21,)), r=5, k=10)
    sm = np.asarray(res.slot_map)
    assert (sm == sm[-1]).sum() == 1
    np.testing.assert_allclose(np.asarray(res.x[sm[-1]]), x[-1], atol=1e-6)


def test_merge_schedule_matches_rust_reference():
    # pinned vector also asserted on the Rust side
    assert merging.merge_schedule(96, r=16, num_layers=4, q=4) == [96, 80, 64, 48, 32]
    assert merging.merge_schedule(10, r=100, num_layers=4, q=4)[-1] == 4
    s = merging.merge_schedule(513, r=64, num_layers=3, q=8)
    assert s[0] == 513 and all(a >= b for a, b in zip(s, s[1:]))
