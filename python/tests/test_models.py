"""L2 model zoo: shapes, merging placement, causality, training dynamics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import train as Tr
from compile.kernels import dispatch
from compile.models import chronos as Ch
from compile.models import hyena as Hy
from compile.models import mamba as Ma
from compile.models import patchtst as Pt
from compile.models import transformer as T

RNG = np.random.default_rng(0)


def fc_cfg(**kw):
    base = dict(arch="transformer", enc_layers=2, m=96, p=48, label_len=24, n_vars=7)
    base.update(kw)
    return T.ForecastConfig(**base)


@pytest.mark.parametrize("arch", ["transformer", "informer", "autoformer",
                                  "fedformer", "nonstationary"])
@pytest.mark.parametrize("r", [0, 16])
def test_forecaster_shapes(arch, r):
    cfg = fc_cfg(arch=arch, r_enc=r, r_dec=16 if r else 0)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.standard_normal((cfg.m, cfg.n_vars)), jnp.float32)
    y = T.forward(params, x, cfg)
    assert y.shape == (cfg.p, cfg.n_vars)
    assert bool(jnp.isfinite(y).all())


def test_forecaster_merging_reduces_tokens():
    cfg = fc_cfg(r_enc=16)
    counts = T.enc_token_counts(cfg)
    assert counts == [96, 80, 64]
    cfg = fc_cfg(r_dec=24)
    assert T.dec_token_counts(cfg) == [72, 48]


def test_probe_outputs():
    cfg = fc_cfg(probe="tokens")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.standard_normal((cfg.m, cfg.n_vars)), jnp.float32)
    y, tokens = T.forward(params, x, cfg)
    assert y.shape == (cfg.p, cfg.n_vars)
    assert tokens.shape == (cfg.m, cfg.d)


def test_trace_probe_is_valid_slot_map():
    cfg = fc_cfg(r_enc=16, probe="trace")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.standard_normal((cfg.m, cfg.n_vars)), jnp.float32)
    _, trace = T.forward(params, x, cfg)
    assert trace.shape == (cfg.m,)
    final = T.enc_token_counts(cfg)[-1]
    assert int(trace.max()) < final
    assert int(trace.min()) >= 0


def test_chronos_tokenizer_roundtrip():
    cfg = Ch.ChronosConfig(m=64, vocab=128)
    x = jnp.asarray(RNG.standard_normal((64,)) * 3, jnp.float32)
    ids, scale = Ch.tokenize(x, cfg)
    assert ids.shape == (64,)
    assert int(ids.min()) >= 0 and int(ids.max()) < cfg.vocab
    centers = Ch.bin_centers(cfg)
    recon = centers[ids] * scale
    # quantization error bounded by half a bin width * scale
    bin_w = 2 * cfg.clip / (cfg.vocab - 1)
    assert float(jnp.abs(recon - x).max()) <= bin_w * float(scale) * 0.51 + 1e-5


def test_chronos_merging_shapes():
    cfg = Ch.ChronosConfig(m=128, p=32, enc_layers=2, r_enc=32, r_dec=8, vocab=64)
    params = Ch.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.standard_normal((cfg.m,)), jnp.float32)
    logits, scale = Ch.forward(params, x, cfg)
    assert logits.shape == (cfg.p, cfg.vocab)
    assert float(scale) > 0


def test_chronos_dynamic_effective_tokens():
    cfg = Ch.ChronosConfig(m=128, p=32, enc_layers=2, vocab=64)
    params = Ch.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.standard_normal((cfg.m,)), jnp.float32)
    _, _, eff_hi = Ch.forward_dynamic(params, x, jnp.float32(2.0), cfg)
    _, _, eff_lo = Ch.forward_dynamic(params, x, jnp.float32(-2.0), cfg)
    assert int(eff_hi) == cfg.m * cfg.enc_layers
    assert int(eff_lo) < int(eff_hi)


@pytest.mark.parametrize("mod,cfg", [
    (Hy, Hy.HyenaConfig(m=256, layers=2, r=32, k=1)),
    (Ma, Ma.MambaConfig(m=256, layers=2, r=32, k=1, d_inner=64)),
])
def test_ssm_classifier_shapes(mod, cfg):
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    ids = jnp.asarray(RNG.integers(0, 5, (cfg.m,)), jnp.int32)
    logits = mod.forward(params, ids, cfg)
    assert logits.shape == (cfg.n_classes,)
    assert bool(jnp.isfinite(logits).all())


def test_patchtst_channel_independence():
    cfg = Pt.PatchTSTConfig(m=192, p=96, r=4)
    params = Pt.init_params(jax.random.PRNGKey(0), cfg)
    x = np.asarray(RNG.standard_normal((192, 7)), np.float32)
    y1 = Pt.forward(params, jnp.asarray(x), cfg)
    # perturbing channel 3 must not change channel 0's forecast
    x2 = x.copy()
    x2[:, 3] += 10.0
    y2 = Pt.forward(params, jnp.asarray(x2), cfg)
    np.testing.assert_allclose(np.asarray(y1[:, 0]), np.asarray(y2[:, 0]), atol=1e-5)
    assert not np.allclose(np.asarray(y1[:, 3]), np.asarray(y2[:, 3]))


def test_decoder_merging_preserves_output_length():
    # unmerge must restore the full horizon regardless of r_dec
    for r_dec in [0, 8, 24]:
        cfg = fc_cfg(r_dec=r_dec)
        params = T.init_params(jax.random.PRNGKey(1), cfg)
        x = jnp.asarray(RNG.standard_normal((cfg.m, cfg.n_vars)), jnp.float32)
        y = T.forward(params, x, cfg)
        assert y.shape == (cfg.p, cfg.n_vars)


def test_train_step_reduces_loss_all_families():
    with dispatch.backend("jnp"):
        # forecaster
        cfg = fc_cfg(r_enc=8, r_dec=8)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        step = jax.jit(Tr.make_forecast_train_step(T.forward_batch, cfg, lr=3e-3))
        xb = jnp.asarray(RNG.standard_normal((4, cfg.m, 7)), jnp.float32)
        yb = jnp.asarray(RNG.standard_normal((4, cfg.p, 7)), jnp.float32) * 0.1
        m = jax.tree.map(jnp.zeros_like, params)
        v = jax.tree.map(jnp.zeros_like, params)
        losses = []
        for i in range(8):
            params, m, v, loss = step(params, m, v, float(i), xb, yb)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.8, losses


def test_merging_during_training_is_differentiable():
    with dispatch.backend("jnp"):
        cfg = fc_cfg(r_enc=16, r_dec=16)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(RNG.standard_normal((2, cfg.m, 7)), jnp.float32)
        y = jnp.asarray(RNG.standard_normal((2, cfg.p, 7)), jnp.float32)
        g = jax.grad(lambda p: Tr.mse_loss(T.forward_batch(p, x, cfg), y))(params)
        flat = jax.tree_util.tree_leaves(g)
        assert all(bool(jnp.isfinite(l).all()) for l in flat)
        # at least one gradient is non-zero
        assert any(float(jnp.abs(l).max()) > 0 for l in flat)


def test_config_dataclasses_are_hashable_and_serializable():
    for cfg in [fc_cfg(), Ch.ChronosConfig(), Hy.HyenaConfig(), Ma.MambaConfig(),
                Pt.PatchTSTConfig()]:
        d = dataclasses.asdict(cfg)
        assert isinstance(d, dict) and d
        hash(cfg)  # frozen dataclasses must hash (used as jit static args)
