"""Interchange formats: weights file, manifests, AOT registry sanity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, formats


def test_weights_roundtrip(tmp_path):
    tree = {
        "enc": [{"w": np.arange(6, dtype=np.float32).reshape(2, 3)}],
        "ids": np.array([1, -2, 3], np.int32),
        "scalar": np.float32(2.5).reshape(()),
    }
    path = tmp_path / "w.bin"
    formats.write_weights(path, tree)
    back = formats.read_weights(path)
    np.testing.assert_array_equal(back["enc/0/w"], tree["enc"][0]["w"])
    np.testing.assert_array_equal(back["ids"], tree["ids"])
    assert back["scalar"].shape == ()


def test_flatten_order_matches_jit_flattening():
    """The manifest contract: formats.flatten_named order == the order
    jax.jit flattens the same pytree (this is what lets Rust bind weights
    positionally)."""
    tree = {"b": {"x": jnp.zeros((2,))}, "a": [jnp.ones((1,)), jnp.zeros((3,))]}
    named = formats.flatten_named(tree)
    leaves = jax.tree_util.tree_leaves(tree)
    assert len(named) == len(leaves)
    for (_, arr), leaf in zip(named, leaves):
        assert arr.shape == leaf.shape


def test_manifest_contains_full_contract(tmp_path):
    params = {"w": jnp.zeros((4, 2))}
    inputs = [("x", jax.ShapeDtypeStruct((8, 16), jnp.float32))]
    outputs = [("out0", jax.ShapeDtypeStruct((8, 4), jnp.float32))]
    path = tmp_path / "m.json"
    formats.write_manifest(path, name="t", family="forecast", config={"m": 16},
                           params_tree=params, inputs=inputs, outputs=outputs,
                           meta={"batch": 8})
    m = json.loads(path.read_text())
    assert m["params"] == [{"name": "w", "shape": [4, 2], "dtype": "f32"}]
    assert m["inputs"][0]["shape"] == [8, 16]
    assert m["meta"]["batch"] == 8


def test_manifest_merge_spec_roundtrip(tmp_path):
    params = {"w": jnp.zeros((2,))}
    inputs = [("x", jax.ShapeDtypeStruct((4,), jnp.float32))]
    outputs = [("out0", jax.ShapeDtypeStruct((4,), jnp.float32))]
    spec = {"mode": "fixed", "k": 10**6, "schedule": [16, 16, 8]}
    path = tmp_path / "m.json"
    formats.write_manifest(path, name="t", family="chronos", config={},
                           params_tree=params, inputs=inputs, outputs=outputs,
                           merge_spec=spec)
    m = json.loads(path.read_text())
    assert m["merge_spec"] == spec
    # omitted entirely when None, so pre-merge_spec manifests keep parsing
    formats.write_manifest(path, name="t", family="chronos", config={},
                           params_tree=params, inputs=inputs, outputs=outputs)
    assert "merge_spec" not in json.loads(path.read_text())


def test_merge_spec_dialect_matches_rust_loader():
    """Pins the exact dicts merge_spec_for emits to the dialect the Rust
    loader parses strictly (config::merge_spec_from_json): mode-dependent
    key subsets, schedule entries >= 1, causal implies k == 1, and the
    k = 0 global pool mapped to the huge-band sentinel."""
    spec = aot.merge_spec_for("chronos", {"k_enc": 4},
                              {"enc_tokens": [512, 448, 384]})
    assert spec == {"mode": "fixed", "k": 4, "schedule": [64, 64]}
    # k_enc = 0 (global pool) maps to the sentinel the kernel clamps to t/2
    spec = aot.merge_spec_for("forecast", {"k_enc": 0},
                              {"enc_tokens": [96, 64, 48]})
    assert spec == {"mode": "fixed", "k": aot.GLOBAL_K, "schedule": [32, 16]}
    # zero-step layers (q_min floor) are dropped: entries stay >= 1
    spec = aot.merge_spec_for("hyena", {"k": 1}, {"tokens": [16, 8, 8, 8]})
    assert spec == {"mode": "fixed", "k": 1, "schedule": [8]}
    # r = 0 variants are an explicit "off" block with no other keys
    assert aot.merge_spec_for("mamba", {"k": 1},
                              {"tokens": [512, 512]}) == {"mode": "off"}
    # decoder-only merging is causal with k = 1, regardless of config
    spec = aot.merge_spec_for("deconly", {}, {"tokens": [32, 24, 16]})
    assert spec == {"mode": "fixed", "k": 1, "schedule": [8, 8],
                    "causal": True}
    # patchtst carries no token meta: schedule recomputed from the
    # patching geometry ((192 - 16) // 8 + 1 = 23 patches, r = 4 x 2 layers)
    cfg = {"m": 192, "patch_len": 16, "stride": 8, "layers": 2, "r": 4,
           "k": 0, "q_min": 4}
    spec = aot.merge_spec_for("patchtst", cfg, {"batch": 8})
    assert spec == {"mode": "fixed", "k": aot.GLOBAL_K, "schedule": [4, 4]}
    # serve-time-rate and training artifacts carry no spec at all
    for fam in ("chronos_dyn", "forecast_train", "chronos_train",
                "deconly_train", "classify_train", "patchtst_train"):
        assert aot.merge_spec_for(fam, {}, {}) is None


def test_registry_names_unique_and_well_formed():
    arts = aot.registry()
    names = [a.name for a in arts]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for a in arts:
        assert "__" in a.name, a.name
        assert a.name.split("__")[0] == a.identity
        assert a.backend in ("jnp", "pallas")


def test_registry_covers_every_experiment():
    """DESIGN.md §5: every table/figure needs its artifacts."""
    names = {a.name for a in aot.registry()}
    required = [
        # table 1 + fig 5
        "fc_transformer_L2__r0", "fc_transformer_L2__r16", "fc_informer_L4__r32",
        "fc_autoformer_L2__train",
        # fig 2
        "fc_autoformer_L2__trainmerge", "fc_nonstationary_L2__trainmerge",
        # table 2 / fig 3
        "chronos_s__r0", "chronos_m__r64", "chronos_l__r128", "chronos_s__train",
        # fig 4
        "chronos_s__dyn_b1", "chronos_s__dyn_b10",
        # figs 15/16, table 5, figs 8/19
        "chronos_s__r64_l1", "chronos_s__r64_prune", "chronos_s__r0_probe",
        "chronos_s__r64_trace", "chronos_s__r0_probe_nope",
        "fc_informer_L2__r0_probe",
        # fig 7
        "chronos_s__m128_r0", "chronos_s__m1024_r128",
        # table 3
        "hyena_L4__r64_k1", "hyena_L4__r128_kglobal", "mamba_L4__r64_k1",
        "mamba_L4__train",
        # table 8
        "patchtst_L2__r4", "patchtst_L2__train",
        # pallas round-trip proofs
        "chronos_s__r64_pallas", "mamba_L2s__r64_pallas",
    ]
    missing = [r for r in required if r not in names]
    assert not missing, f"registry missing {missing}"


def test_identity_shares_weights_across_variants():
    arts = aot.registry()
    by_identity = {}
    for a in arts:
        by_identity.setdefault(a.identity, []).append(a.name)
    # chronos_s has many variants, all binding one weights file
    assert len(by_identity["chronos_s"]) >= 8


@pytest.mark.slow
def test_lower_artifact_is_idempotent(tmp_path):
    art = next(a for a in aot.registry() if a.name == "patchtst_L2__r4")
    assert aot.lower_artifact(art, str(tmp_path), force=True) == "ok"
    assert aot.lower_artifact(art, str(tmp_path), force=False) == "skip"
    assert (tmp_path / "patchtst_L2__r4.hlo.txt").exists()
    assert (tmp_path / "patchtst_L2.weights.bin").exists()
    manifest = json.loads((tmp_path / "patchtst_L2__r4.json").read_text())
    hlo = (tmp_path / "patchtst_L2__r4.hlo.txt").read_text()
    # every manifest param + input must appear as an HLO parameter
    n_params = len(manifest["params"]) + len(manifest["inputs"])
    assert hlo.count("parameter(") >= n_params
    assert "largest=true" not in hlo  # 0.5.1 parser compatibility shim
    # lowering wires the derived merge_spec into the manifest
    assert manifest["merge_spec"] == aot.merge_spec_for(
        "patchtst", manifest["config"], manifest["meta"])
    assert manifest["merge_spec"]["mode"] == "fixed"
