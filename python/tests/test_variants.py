"""Architecture-defining mechanisms of the table-1 variants + decoder-only.

Each test pins the *behaviour that makes the architecture what it is*:
ProbSparse sparsity, auto-correlation period detection, frequency-domain
mixing, stationarization, causal decoder-only merging.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import common as C
from compile.models import decoder_only as Do
from compile.models import variants as V

RNG = np.random.default_rng(0)


def mk_attn(arch, d=32, heads=4, seed=0):
    return V.attention_init(jax.random.PRNGKey(seed), d, heads, arch=arch)


def test_probsparse_lazy_queries_emit_mean_value():
    """Informer: non-active queries output mean(V) — different active sets
    give identical outputs on lazy positions."""
    d, heads, t = 32, 4, 64
    p = mk_attn("informer")
    x = jnp.asarray(RNG.standard_normal((t, d)), jnp.float32)
    bias = jnp.zeros((t, t))
    out = V.probsparse_attention(p, x, x, heads=heads, bias=bias)
    full = V.vanilla_attention(p, x, x, heads=heads, bias=bias)
    # ProbSparse must differ from full attention (some queries are lazy)
    assert not np.allclose(np.asarray(out), np.asarray(full), atol=1e-4)
    assert np.isfinite(np.asarray(out)).all()


def test_autocorrelation_detects_period():
    """Autoformer: for a periodic token sequence the top delay weight mass
    concentrates on multiples of the period."""
    d, heads, t, period = 16, 2, 64, 16
    p = mk_attn("autoformer", d=d, heads=heads)
    # token features repeat with the period exactly
    base = RNG.standard_normal((period, d)).astype(np.float32)
    x = jnp.asarray(np.tile(base, (t // period, 1)))
    q = C.split_heads(C.dense(p["wq"], x), heads)
    k = C.split_heads(C.dense(p["wk"], x), heads)
    fq = jnp.fft.rfft(q, axis=1)
    fk = jnp.fft.rfft(k, axis=1)
    r = jnp.mean(jnp.fft.irfft(fq * jnp.conj(fk), n=t, axis=1), axis=-1)
    r = np.asarray(r)  # (h, t) correlation per delay
    # q and k use different projections, so the absolute peak offset is
    # arbitrary — but with period-16 tokens the correlation itself must be
    # 16-periodic: the top-4 delays are congruent mod the period.
    for h in range(heads):
        top4 = np.argsort(-r[h])[:4]
        assert len({int(tau) % period for tau in top4}) == 1, f"head {h}: {top4}"


def test_frequency_attention_bandlimits():
    """FEDformer: output spectrum is supported only on the retained modes."""
    d, heads, t = 16, 2, 64
    p = mk_attn("fedformer", d=d, heads=heads)
    x = jnp.asarray(RNG.standard_normal((t, d)), jnp.float32)
    out = V.frequency_attention(p, x, x, heads=heads, bias=jnp.zeros((t, t)), modes=4)
    # undo the output projection to inspect the mixed signal's spectrum
    w = np.asarray(p["wo"]["w"])
    y = (np.asarray(out) - np.asarray(p["wo"]["b"])) @ np.linalg.pinv(w)
    spec = np.abs(np.fft.rfft(y, axis=0)).sum(-1)
    kept = np.sort(np.argsort(spec)[-4:])
    # beyond the 4 retained modes, energy ~ 0
    others = np.delete(spec, kept)
    assert others.max() < 1e-3 * max(spec.max(), 1e-9), (kept, others.max())


def test_destationary_attention_uses_tau_delta():
    d, heads, t = 32, 4, 48
    p = mk_attn("nonstationary")
    x = jnp.asarray(RNG.standard_normal((t, d)), jnp.float32)
    bias = jnp.zeros((t, t))
    out1 = V.destationary_attention(p, x, x, heads=heads, bias=bias,
                                    tau=jnp.float32(1.0), delta=jnp.zeros((t,)))
    out2 = V.destationary_attention(p, x, x, heads=heads, bias=bias,
                                    tau=jnp.float32(3.0), delta=jnp.ones((t,)))
    base = V.vanilla_attention(p, x, x, heads=heads, bias=bias)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(base), atol=1e-5)
    assert not np.allclose(np.asarray(out1), np.asarray(out2), atol=1e-4)


def test_decomposition_splits_trend():
    t = np.linspace(0, 4, 128, dtype=np.float32)
    x = jnp.asarray((t * 2.0 + np.sin(2 * np.pi * 8 * t)).reshape(-1, 1))
    seasonal, trend = C.series_decomp(x, win=25)
    # trend carries the slope, seasonal is ~zero-mean
    assert abs(float(seasonal.mean())) < 0.1
    assert float(trend[-1, 0] - trend[0, 0]) > 5.0


def test_deconly_forward_and_merging():
    cfg = Do.DecoderOnlyConfig(m=256, p=32, layers=2, r=2)
    params = Do.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.standard_normal((cfg.m,)), jnp.float32)
    y = Do.forward(params, x, cfg)
    assert y.shape == (cfg.p,)
    assert Do.token_counts(cfg) == [16, 14, 12]


def test_deconly_causality_under_merging():
    """Perturbing the earliest patch may change the forecast, but the
    forecast from a context whose *future* patches are identical must be
    identical when only pre-context values differ -> check merging does not
    leak future info: perturbing the LAST patch must change the output
    (it is the prediction token), while outputs are deterministic."""
    cfg = Do.DecoderOnlyConfig(m=256, p=32, layers=2, r=2)
    params = Do.init_params(jax.random.PRNGKey(1), cfg)
    x = RNG.standard_normal((cfg.m,)).astype(np.float32)
    y1 = np.asarray(Do.forward(params, jnp.asarray(x), cfg))
    y2 = np.asarray(Do.forward(params, jnp.asarray(x), cfg))
    np.testing.assert_array_equal(y1, y2)
    x_pert = x.copy()
    x_pert[-1] += 5.0
    y3 = np.asarray(Do.forward(params, jnp.asarray(x_pert), cfg))
    assert not np.allclose(y1, y3)


def test_deconly_scale_equivariance():
    """Mean-scaling makes the forecaster amplitude-equivariant."""
    cfg = Do.DecoderOnlyConfig(m=256, p=32, layers=2, r=0)
    params = Do.init_params(jax.random.PRNGKey(2), cfg)
    x = RNG.standard_normal((cfg.m,)).astype(np.float32)
    y1 = np.asarray(Do.forward(params, jnp.asarray(x), cfg))
    y2 = np.asarray(Do.forward(params, jnp.asarray(x * 10.0), cfg))
    np.testing.assert_allclose(y2, y1 * 10.0, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("arch", ["informer", "autoformer", "fedformer"])
def test_variant_attention_is_finite_under_merged_sizes(arch):
    """Every flavour must accept proportional-attention biases from merged
    tokens (log sizes)."""
    d, heads, t = 32, 4, 40
    p = mk_attn(arch)
    x = jnp.asarray(RNG.standard_normal((t, d)), jnp.float32)
    sizes = jnp.asarray(RNG.integers(1, 6, (t,)), jnp.float32)
    bias = C.size_bias(sizes, t)
    out = V.ATTENTION[arch](p, x, x, heads=heads, bias=bias)
    assert out.shape == (t, d)
    assert np.isfinite(np.asarray(out)).all()
